//! Configuration system: model/hardware presets, scheduler knobs, QoS
//! tiers, cluster topology. Loadable from JSON files; every field has a
//! paper-faithful default so `Config::default()` reproduces the paper's
//! evaluation setup (Llama3-8B on one A100, Table 2 tiers).

use crate::qos::{table2_tiers, QosTier, Slo};
use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};

/// Model + hardware description used by the analytic cost model.
/// Defaults describe Llama3-8B (fp16) on a single A100-80GB — the paper's
/// primary testbed.
#[derive(Debug, Clone)]
pub struct HardwareModel {
    pub name: String,
    /// Model parameters (weights), count.
    pub n_params: f64,
    /// Transformer layer count.
    pub n_layers: f64,
    /// Attention hidden size (q heads * head dim).
    pub d_model: f64,
    /// KV-cache bytes per token (all layers, K+V).
    pub kv_bytes_per_token: f64,
    /// Weight bytes resident in HBM.
    pub weight_bytes: f64,
    /// Peak matmul throughput, FLOP/s (A100 fp16 dense: 312e12).
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// HBM capacity, bytes.
    pub hbm_bytes: f64,
    /// Half-saturation batch size of the MFU curve: efficiency =
    /// tokens / (tokens + mfu_half). Calibrated so chunk 256 runs ~28%
    /// below chunk 2048 throughput (paper Fig. 4).
    pub mfu_half: f64,
    /// Fixed per-iteration overhead, seconds (launch + scheduler).
    pub iteration_overhead_s: f64,
    /// Tensor-parallel degree (adds a per-iteration collective term).
    pub tp_degree: u32,
    /// Per-iteration collective overhead per TP rank pair, seconds.
    pub tp_overhead_s: f64,
}

impl HardwareModel {
    /// Llama3-8B on one A100-80GB (paper's primary setup).
    pub fn llama3_8b_a100() -> Self {
        HardwareModel {
            name: "llama3-8b-a100".into(),
            n_params: 8.0e9,
            n_layers: 32.0,
            d_model: 4096.0,
            // GQA: 8 KV heads * 128 dim * 2 (K+V) * 2 bytes * 32 layers.
            kv_bytes_per_token: 8.0 * 128.0 * 2.0 * 2.0 * 32.0,
            weight_bytes: 16.0e9,
            peak_flops: 312.0e12,
            hbm_bw: 2.0e12,
            hbm_bytes: 80.0e9,
            mfu_half: 120.0,
            iteration_overhead_s: 1.5e-3,
            tp_degree: 1,
            tp_overhead_s: 0.0,
        }
    }

    /// Qwen-7B across two A100s with tensor parallelism (paper's second
    /// setup).
    pub fn qwen_7b_a100_tp2() -> Self {
        HardwareModel {
            name: "qwen-7b-a100-tp2".into(),
            n_params: 7.0e9,
            n_layers: 32.0,
            d_model: 4096.0,
            // MHA: 32 KV heads * 128 dim * 2 * 2 bytes * 32 layers.
            kv_bytes_per_token: 32.0 * 128.0 * 2.0 * 2.0 * 32.0,
            weight_bytes: 14.0e9,
            peak_flops: 2.0 * 312.0e12 * 0.9, // TP efficiency factor
            hbm_bw: 2.0 * 2.0e12,
            hbm_bytes: 2.0 * 80.0e9,
            mfu_half: 150.0,
            iteration_overhead_s: 1.5e-3,
            tp_degree: 2,
            tp_overhead_s: 0.7e-3,
        }
    }

    /// The validation model served by the real PJRT CPU path: the ~7.3M
    /// parameter transformer in `artifacts/`. Constants approximate a
    /// laptop-class CPU; the serving loop refits a predictor from
    /// measured iterations anyway (`runtime::calibrate`).
    pub fn tiny_cpu() -> Self {
        HardwareModel {
            name: "tiny-cpu".into(),
            n_params: 7.3e6,
            n_layers: 4.0,
            d_model: 256.0,
            // 4 KV heads * 32 dim * 2 (K+V) * 4 bytes * 4 layers.
            kv_bytes_per_token: 4.0 * 32.0 * 2.0 * 4.0 * 4.0,
            weight_bytes: 30.0e6,
            peak_flops: 5.0e10,
            hbm_bw: 2.0e10,
            hbm_bytes: 2.0e9,
            mfu_half: 64.0,
            iteration_overhead_s: 10.0e-3,
            tp_degree: 1,
            tp_overhead_s: 0.0,
        }
    }

    /// KV-cache token capacity after weights + activation reserve.
    pub fn kv_capacity_tokens(&self) -> u64 {
        let reserve = 0.1 * self.hbm_bytes; // activations + fragmentation
        let avail = self.hbm_bytes - self.weight_bytes * self.tp_degree as f64 - reserve;
        (avail.max(0.0) / self.kv_bytes_per_token) as u64
    }
}

/// Scheduling policy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// The paper's system: dynamic chunking + hybrid priority + relegation.
    Niyama,
    /// Sarathi with first-come-first-served prefill order.
    SarathiFcfs,
    /// Sarathi with earliest-deadline-first prefill order.
    SarathiEdf,
    /// Sarathi with shortest-remaining-prompt-first prefill order.
    SarathiSrpf,
    /// Sarathi with shortest-job-first (total estimated work) order.
    SarathiSjf,
}

impl Policy {
    pub fn parse(s: &str) -> Result<Policy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "niyama" => Policy::Niyama,
            "fcfs" | "sarathi-fcfs" => Policy::SarathiFcfs,
            "edf" | "sarathi-edf" => Policy::SarathiEdf,
            "srpf" | "sarathi-srpf" => Policy::SarathiSrpf,
            "sjf" | "sarathi-sjf" => Policy::SarathiSjf,
            other => bail!("unknown policy '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Niyama => "niyama",
            Policy::SarathiFcfs => "sarathi-fcfs",
            Policy::SarathiEdf => "sarathi-edf",
            Policy::SarathiSrpf => "sarathi-srpf",
            Policy::SarathiSjf => "sarathi-sjf",
        }
    }
}

/// Scheduler knobs (paper §3 + §4.4 ablations).
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    pub policy: Policy,
    /// Fixed chunk size for the Sarathi baselines; also Niyama's floor.
    pub chunk_size: u32,
    /// Upper bound for dynamic chunking.
    pub max_chunk_size: u32,
    /// Max decode requests batched per iteration.
    pub max_batch_decodes: usize,
    /// Hybrid-prioritization interpolation factor alpha (eqs. 4-5).
    pub alpha: f64,
    /// Scale alpha with observed load (paper §4.2: "adjusts the alpha
    /// parameter" during overload).
    pub adaptive_alpha: bool,
    /// Ablation switches (Table 3).
    pub dynamic_chunking: bool,
    pub eager_relegation: bool,
    pub hybrid_priority: bool,
    /// Selective preemption of in-prefill requests (paper §3.4).
    pub selective_preemption: bool,
    /// Cap on the fraction of requests that may be relegated (Fig. 5
    /// sweeps this; 1.0 = unlimited).
    pub relegation_cap: f64,
    /// Safety margin subtracted from predicted latency headroom, seconds.
    pub slack_margin_s: f64,
    /// Price scheduling probes by re-evaluating the full batch shape
    /// instead of the O(1) incremental accumulator. Slow — exists only
    /// as the oracle the equivalence tests hold the fast path against;
    /// never enable it in experiments.
    pub reference_costing: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            policy: Policy::Niyama,
            chunk_size: 256,
            max_chunk_size: 2048,
            max_batch_decodes: 256,
            alpha: 0.5,
            adaptive_alpha: true,
            dynamic_chunking: true,
            eager_relegation: true,
            hybrid_priority: true,
            selective_preemption: true,
            relegation_cap: 1.0,
            slack_margin_s: 2.0e-3,
            reference_costing: false,
        }
    }
}

impl SchedulerConfig {
    /// The paper's Sarathi baseline at a given policy: fixed chunks, no
    /// Niyama machinery.
    pub fn sarathi(policy: Policy, chunk_size: u32) -> Self {
        SchedulerConfig {
            policy,
            chunk_size,
            max_chunk_size: chunk_size,
            dynamic_chunking: false,
            eager_relegation: false,
            hybrid_priority: false,
            selective_preemption: false,
            adaptive_alpha: false,
            alpha: 0.0,
            ..SchedulerConfig::default()
        }
    }
}

/// Global dispatch policy: how the cluster front-end routes each arrival
/// to a replica (see `simulator::dispatch`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Stateless rotation — the seed's behavior and the standard
    /// load-oblivious front-end baseline.
    RoundRobin,
    /// Route to the replica with the fewest requests awaiting prefill.
    JoinShortestQueue,
    /// QoS/slack-aware: route on live load snapshots (queued prefill
    /// seconds, KV pressure, per-tier slack headroom), preferring
    /// replicas that can still meet the arrival's deadline.
    LeastLoaded,
    /// Sample two random replicas, route to the lower-pressure one
    /// (the `LeastLoaded` score on just the pair). O(1) per arrival
    /// regardless of replica count — the classic balanced-allocations
    /// result keeps the max load within O(log log R) of optimal.
    PowerOfTwoChoices,
    /// Power-of-two-choices sampling scored by the fitted per-replica
    /// latency predictor instead of `LeastLoaded`'s linear token rate:
    /// the predicted TTFT accounts for the candidate's live decode load
    /// inflating every prefill chunk it would serve ahead of this
    /// arrival.
    PredictedTtft,
}

impl DispatchPolicy {
    pub fn parse(s: &str) -> Result<DispatchPolicy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => DispatchPolicy::RoundRobin,
            "join-shortest-queue" | "jsq" => DispatchPolicy::JoinShortestQueue,
            "least-loaded" | "ll" => DispatchPolicy::LeastLoaded,
            "power-of-two-choices" | "p2c" => DispatchPolicy::PowerOfTwoChoices,
            "predicted-ttft" | "pttft" => DispatchPolicy::PredictedTtft,
            other => bail!("unknown dispatch policy '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round-robin",
            DispatchPolicy::JoinShortestQueue => "join-shortest-queue",
            DispatchPolicy::LeastLoaded => "least-loaded",
            DispatchPolicy::PowerOfTwoChoices => "power-of-two-choices",
            DispatchPolicy::PredictedTtft => "predicted-ttft",
        }
    }
}

/// Cluster dispatch knobs.
#[derive(Debug, Clone)]
pub struct DispatchConfig {
    pub policy: DispatchPolicy,
    /// Llumnix-style cross-replica relegation handoff: requests a replica
    /// relegates may be re-dispatched to a replica with spare headroom.
    pub relegation_handoff: bool,
    /// Seed for randomized policies (power-of-two-choices sampling);
    /// runs are bit-reproducible for a fixed seed.
    pub seed: u64,
}

impl Default for DispatchConfig {
    fn default() -> Self {
        // Round-robin without handoff reproduces the seed's static shard
        // split exactly, so existing experiments are unchanged by default.
        DispatchConfig { policy: DispatchPolicy::RoundRobin, relegation_handoff: false, seed: 0 }
    }
}

/// Elastic control-plane policy selector (see `simulator::control`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AutoscalePolicy {
    /// Static replica set — the pre-control-plane behavior.
    Off,
    /// Hysteresis on queued-prefill-seconds per replica / KV pressure:
    /// scale when the signal stays past a watermark for `hold_s`.
    Reactive,
    /// Tier-slack-aware predictive control: project queue growth over
    /// the warm-up horizon and order capacity before the strictest
    /// tier's slack is exhausted.
    Predictive,
}

impl AutoscalePolicy {
    pub fn parse(s: &str) -> Result<AutoscalePolicy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "off" | "none" | "static" => AutoscalePolicy::Off,
            "reactive" | "hysteresis" => AutoscalePolicy::Reactive,
            "predictive" | "tier-slack" => AutoscalePolicy::Predictive,
            other => bail!("unknown autoscale policy '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AutoscalePolicy::Off => "off",
            AutoscalePolicy::Reactive => "reactive",
            AutoscalePolicy::Predictive => "predictive",
        }
    }
}

/// Elastic control-plane knobs: autoscaler bounds and signals plus the
/// global admission policy applied at the dispatcher.
#[derive(Debug, Clone)]
pub struct ControlConfig {
    pub autoscale: AutoscalePolicy,
    /// Lower bound on serving (active + warming) replicas.
    pub min_replicas: usize,
    /// Upper bound on serving replicas.
    pub max_replicas: usize,
    /// Cold-start seconds between provisioning a replica and the engine
    /// accepting work.
    pub warmup_s: f64,
    /// Controller evaluation period on the shared virtual clock.
    pub control_interval_s: f64,
    /// Scale-up watermark: queued prefill seconds per serving replica.
    pub scale_up_queue_s: f64,
    /// Scale-down watermark (must not exceed the scale-up watermark).
    pub scale_down_queue_s: f64,
    /// How long a watermark must hold before the controller acts.
    pub hold_s: f64,
    /// Global admission control applied to every arrival at dispatch.
    pub admission: crate::simulator::dispatch::AdmissionPolicy,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            autoscale: AutoscalePolicy::Off,
            min_replicas: 1,
            max_replicas: 8,
            warmup_s: 20.0,
            control_interval_s: 5.0,
            scale_up_queue_s: 4.0,
            scale_down_queue_s: 0.5,
            hold_s: 10.0,
            admission: crate::simulator::dispatch::AdmissionPolicy::None,
        }
    }
}

/// Cluster topology for multi-replica serving / silo experiments.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of identical replicas sharing the workload.
    pub replicas: usize,
    /// How arrivals are routed across those replicas.
    pub dispatch: DispatchConfig,
    /// Elastic control plane: autoscaling + admission control.
    pub control: ControlConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: 1,
            dispatch: DispatchConfig::default(),
            control: ControlConfig::default(),
        }
    }
}

/// Top-level system configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub hardware: HardwareModel,
    pub scheduler: SchedulerConfig,
    pub tiers: Vec<QosTier>,
    pub cluster: ClusterConfig,
    /// Random seed for workload generation.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            hardware: HardwareModel::llama3_8b_a100(),
            scheduler: SchedulerConfig::default(),
            tiers: table2_tiers(),
            cluster: ClusterConfig::default(),
            seed: 0,
        }
    }
}

impl Config {
    /// Load a config from a JSON file; unspecified fields keep defaults.
    pub fn from_file(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::from_json_str(&text)
    }

    pub fn from_json_str(text: &str) -> Result<Config> {
        let j = Json::parse(text).map_err(|e| anyhow!("config parse: {e}"))?;
        let mut cfg = Config::default();

        if let Some(hw) = j.get("hardware") {
            if let Some(name) = hw.get("preset").and_then(|v| v.as_str()) {
                cfg.hardware = match name {
                    "llama3-8b-a100" => HardwareModel::llama3_8b_a100(),
                    "qwen-7b-a100-tp2" => HardwareModel::qwen_7b_a100_tp2(),
                    other => bail!("unknown hardware preset '{other}'"),
                };
            }
            override_f64(hw, "peak_flops", &mut cfg.hardware.peak_flops);
            override_f64(hw, "hbm_bw", &mut cfg.hardware.hbm_bw);
            override_f64(hw, "hbm_bytes", &mut cfg.hardware.hbm_bytes);
            override_f64(hw, "mfu_half", &mut cfg.hardware.mfu_half);
            override_f64(hw, "iteration_overhead_s", &mut cfg.hardware.iteration_overhead_s);
        }

        if let Some(s) = j.get("scheduler") {
            if let Some(p) = s.get("policy").and_then(|v| v.as_str()) {
                cfg.scheduler.policy = Policy::parse(p)?;
            }
            override_u32(s, "chunk_size", &mut cfg.scheduler.chunk_size)?;
            override_u32(s, "max_chunk_size", &mut cfg.scheduler.max_chunk_size)?;
            override_f64(s, "alpha", &mut cfg.scheduler.alpha);
            override_f64(s, "relegation_cap", &mut cfg.scheduler.relegation_cap);
            override_bool(s, "dynamic_chunking", &mut cfg.scheduler.dynamic_chunking);
            override_bool(s, "eager_relegation", &mut cfg.scheduler.eager_relegation);
            override_bool(s, "hybrid_priority", &mut cfg.scheduler.hybrid_priority);
            override_bool(s, "adaptive_alpha", &mut cfg.scheduler.adaptive_alpha);
            override_bool(s, "selective_preemption", &mut cfg.scheduler.selective_preemption);
            if let Some(v) = s.get("max_batch_decodes").and_then(|v| v.as_usize()) {
                cfg.scheduler.max_batch_decodes = v;
            }
        }

        if let Some(tiers) = j.get("tiers").and_then(|v| v.as_arr()) {
            cfg.tiers = tiers.iter().map(parse_tier).collect::<Result<_>>()?;
        }

        if let Some(c) = j.get("cluster") {
            if let Some(v) = c.get("replicas").and_then(|v| v.as_usize()) {
                cfg.cluster.replicas = v;
            }
            if let Some(p) = c.get("dispatch").and_then(|v| v.as_str()) {
                cfg.cluster.dispatch.policy = DispatchPolicy::parse(p)?;
            }
            override_bool(c, "relegation_handoff", &mut cfg.cluster.dispatch.relegation_handoff);
            if let Some(v) = c.get("dispatch_seed").and_then(|v| v.as_f64()) {
                cfg.cluster.dispatch.seed = v as u64;
            }
            if let Some(ctl) = c.get("control") {
                let k = &mut cfg.cluster.control;
                if let Some(p) = ctl.get("autoscale").and_then(|v| v.as_str()) {
                    k.autoscale = AutoscalePolicy::parse(p)?;
                }
                if let Some(v) = ctl.get("min_replicas").and_then(|v| v.as_usize()) {
                    k.min_replicas = v;
                }
                if let Some(v) = ctl.get("max_replicas").and_then(|v| v.as_usize()) {
                    k.max_replicas = v;
                }
                override_f64(ctl, "warmup_s", &mut k.warmup_s);
                override_f64(ctl, "control_interval_s", &mut k.control_interval_s);
                override_f64(ctl, "scale_up_queue_s", &mut k.scale_up_queue_s);
                override_f64(ctl, "scale_down_queue_s", &mut k.scale_down_queue_s);
                override_f64(ctl, "hold_s", &mut k.hold_s);
                if let Some(p) = ctl.get("admission").and_then(|v| v.as_str()) {
                    k.admission = crate::simulator::dispatch::AdmissionPolicy::parse(p)?;
                }
            }
        }

        if let Some(v) = j.get("seed").and_then(|v| v.as_f64()) {
            cfg.seed = v as u64;
        }

        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.tiers.is_empty() {
            bail!("at least one QoS tier is required");
        }
        if self.scheduler.chunk_size == 0 {
            bail!("chunk_size must be positive");
        }
        if self.scheduler.max_chunk_size < self.scheduler.chunk_size {
            bail!("max_chunk_size must be >= chunk_size");
        }
        if !(0.0..=1.0).contains(&self.scheduler.relegation_cap) {
            bail!("relegation_cap must be in [0, 1]");
        }
        if self.cluster.replicas == 0 {
            bail!("cluster needs at least one replica");
        }
        let k = &self.cluster.control;
        if k.min_replicas == 0 {
            bail!("control.min_replicas must be at least 1");
        }
        if k.max_replicas < k.min_replicas {
            bail!("control.max_replicas must be >= control.min_replicas");
        }
        if k.control_interval_s <= 0.0 {
            bail!("control.control_interval_s must be positive");
        }
        if k.warmup_s < 0.0 {
            bail!("control.warmup_s must be non-negative");
        }
        if k.scale_down_queue_s > k.scale_up_queue_s {
            bail!("control.scale_down_queue_s must not exceed scale_up_queue_s");
        }
        Ok(())
    }
}

fn parse_tier(j: &Json) -> Result<QosTier> {
    let name = j
        .get("name")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("tier missing 'name'"))?;
    let slo = if let Some(ttlt) = j.get("ttlt_s").and_then(|v| v.as_f64()) {
        Slo::NonInteractive { ttlt_s: ttlt }
    } else {
        let ttft = j
            .get("ttft_s")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("tier '{name}' needs ttft_s+tbt_s or ttlt_s"))?;
        let tbt = j
            .get("tbt_s")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("tier '{name}' needs tbt_s"))?;
        Slo::Interactive { ttft_s: ttft, tbt_s: tbt }
    };
    Ok(QosTier { name: name.to_string(), slo })
}

fn override_f64(j: &Json, key: &str, slot: &mut f64) {
    if let Some(v) = j.get(key).and_then(|v| v.as_f64()) {
        *slot = v;
    }
}

fn override_u32(j: &Json, key: &str, slot: &mut u32) -> Result<()> {
    if let Some(v) = j.get(key) {
        let n = v.as_usize().ok_or_else(|| anyhow!("'{key}' must be a non-negative integer"))?;
        *slot = n as u32;
    }
    Ok(())
}

fn override_bool(j: &Json, key: &str, slot: &mut bool) {
    if let Some(v) = j.get(key).and_then(|v| v.as_bool()) {
        *slot = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_setup() {
        let c = Config::default();
        assert_eq!(c.tiers.len(), 3);
        assert_eq!(c.scheduler.policy, Policy::Niyama);
        assert_eq!(c.scheduler.chunk_size, 256);
        assert_eq!(c.hardware.name, "llama3-8b-a100");
        c.validate().unwrap();
    }

    #[test]
    fn kv_capacity_reasonable_for_a100() {
        let hw = HardwareModel::llama3_8b_a100();
        let cap = hw.kv_capacity_tokens();
        // ~(80 - 16 - 8) GB / 131 KB ≈ 430k tokens.
        assert!(cap > 300_000 && cap < 600_000, "capacity {cap}");
    }

    #[test]
    fn json_overrides() {
        let c = Config::from_json_str(
            r#"{
                "scheduler": {"policy": "sarathi-edf", "chunk_size": 128,
                              "dynamic_chunking": false, "alpha": 0.25},
                "cluster": {"replicas": 4},
                "seed": 7
            }"#,
        )
        .unwrap();
        assert_eq!(c.scheduler.policy, Policy::SarathiEdf);
        assert_eq!(c.scheduler.chunk_size, 128);
        assert!(!c.scheduler.dynamic_chunking);
        assert_eq!(c.scheduler.alpha, 0.25);
        assert_eq!(c.cluster.replicas, 4);
        assert_eq!(c.seed, 7);
    }

    #[test]
    fn json_custom_tiers() {
        let c = Config::from_json_str(
            r#"{"tiers": [
                {"name": "chat", "ttft_s": 2.0, "tbt_s": 0.03},
                {"name": "batch", "ttlt_s": 900}
            ]}"#,
        )
        .unwrap();
        assert_eq!(c.tiers.len(), 2);
        assert_eq!(c.tiers[0].slo, Slo::Interactive { ttft_s: 2.0, tbt_s: 0.03 });
        assert_eq!(c.tiers[1].slo, Slo::NonInteractive { ttlt_s: 900.0 });
    }

    #[test]
    fn rejects_bad_policy() {
        assert!(Config::from_json_str(r#"{"scheduler": {"policy": "lifo"}}"#).is_err());
    }

    #[test]
    fn rejects_invalid_chunk_relation() {
        let r = Config::from_json_str(
            r#"{"scheduler": {"chunk_size": 512, "max_chunk_size": 128}}"#,
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_zero_replicas() {
        assert!(Config::from_json_str(r#"{"cluster": {"replicas": 0}}"#).is_err());
    }

    #[test]
    fn dispatch_defaults_to_seed_behavior() {
        let c = Config::default();
        assert_eq!(c.cluster.dispatch.policy, DispatchPolicy::RoundRobin);
        assert!(!c.cluster.dispatch.relegation_handoff);
    }

    #[test]
    fn json_dispatch_overrides() {
        let c = Config::from_json_str(
            r#"{"cluster": {"replicas": 8, "dispatch": "least-loaded",
                            "relegation_handoff": true}}"#,
        )
        .unwrap();
        assert_eq!(c.cluster.replicas, 8);
        assert_eq!(c.cluster.dispatch.policy, DispatchPolicy::LeastLoaded);
        assert!(c.cluster.dispatch.relegation_handoff);
    }

    #[test]
    fn rejects_unknown_dispatch_policy() {
        assert!(Config::from_json_str(r#"{"cluster": {"dispatch": "random"}}"#).is_err());
    }

    #[test]
    fn dispatch_policy_names_round_trip() {
        for p in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::JoinShortestQueue,
            DispatchPolicy::LeastLoaded,
            DispatchPolicy::PowerOfTwoChoices,
            DispatchPolicy::PredictedTtft,
        ] {
            assert_eq!(DispatchPolicy::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn control_defaults_are_off_and_valid() {
        let c = Config::default();
        assert_eq!(c.cluster.control.autoscale, AutoscalePolicy::Off);
        assert_eq!(
            c.cluster.control.admission,
            crate::simulator::dispatch::AdmissionPolicy::None
        );
        c.validate().unwrap();
    }

    #[test]
    fn json_control_overrides() {
        let c = Config::from_json_str(
            r#"{"cluster": {"replicas": 2, "control": {
                "autoscale": "predictive", "min_replicas": 2, "max_replicas": 6,
                "warmup_s": 15, "control_interval_s": 2.5,
                "scale_up_queue_s": 3, "scale_down_queue_s": 0.25,
                "hold_s": 5, "admission": "degrade"}}}"#,
        )
        .unwrap();
        let k = &c.cluster.control;
        assert_eq!(k.autoscale, AutoscalePolicy::Predictive);
        assert_eq!(k.min_replicas, 2);
        assert_eq!(k.max_replicas, 6);
        assert_eq!(k.warmup_s, 15.0);
        assert_eq!(k.control_interval_s, 2.5);
        assert_eq!(k.admission, crate::simulator::dispatch::AdmissionPolicy::Degrade);
    }

    #[test]
    fn rejects_bad_control_bounds() {
        assert!(Config::from_json_str(
            r#"{"cluster": {"control": {"min_replicas": 4, "max_replicas": 2}}}"#
        )
        .is_err());
        assert!(Config::from_json_str(r#"{"cluster": {"control": {"min_replicas": 0}}}"#)
            .is_err());
        assert!(Config::from_json_str(
            r#"{"cluster": {"control": {"autoscale": "magic"}}}"#
        )
        .is_err());
    }

    #[test]
    fn autoscale_policy_names_round_trip() {
        for p in [AutoscalePolicy::Off, AutoscalePolicy::Reactive, AutoscalePolicy::Predictive] {
            assert_eq!(AutoscalePolicy::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn json_dispatch_seed_override() {
        let c = Config::from_json_str(
            r#"{"cluster": {"replicas": 4, "dispatch": "p2c", "dispatch_seed": 99}}"#,
        )
        .unwrap();
        assert_eq!(c.cluster.dispatch.policy, DispatchPolicy::PowerOfTwoChoices);
        assert_eq!(c.cluster.dispatch.seed, 99);
    }

    #[test]
    fn policy_names_round_trip() {
        for p in [
            Policy::Niyama,
            Policy::SarathiFcfs,
            Policy::SarathiEdf,
            Policy::SarathiSrpf,
            Policy::SarathiSjf,
        ] {
            assert_eq!(Policy::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn shipped_config_files_load() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
        for name in ["shared_niyama.json", "sarathi_edf_baseline.json", "qwen_tp2.json"] {
            let path = dir.join(name);
            let cfg = Config::from_file(path.to_str().unwrap())
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            cfg.validate().unwrap();
        }
        // And spot-check a value from each.
        let edf = Config::from_file(dir.join("sarathi_edf_baseline.json").to_str().unwrap()).unwrap();
        assert_eq!(edf.scheduler.policy, Policy::SarathiEdf);
        let qwen = Config::from_file(dir.join("qwen_tp2.json").to_str().unwrap()).unwrap();
        assert_eq!(qwen.hardware.tp_degree, 2);
    }

    #[test]
    fn sarathi_preset_disables_niyama_features() {
        let s = SchedulerConfig::sarathi(Policy::SarathiFcfs, 256);
        assert!(!s.dynamic_chunking && !s.eager_relegation && !s.hybrid_priority);
        assert_eq!(s.max_chunk_size, 256);
    }
}
