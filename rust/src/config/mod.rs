//! Configuration system: model/hardware presets, scheduler knobs, QoS
//! tiers, cluster topology. Loadable from JSON files; every field has a
//! paper-faithful default so `Config::default()` reproduces the paper's
//! evaluation setup (Llama3-8B on one A100, Table 2 tiers).

use crate::qos::{table2_tiers, QosTier, Slo};
use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};

/// Model + hardware description used by the analytic cost model.
/// Defaults describe Llama3-8B (fp16) on a single A100-80GB — the paper's
/// primary testbed.
#[derive(Debug, Clone)]
pub struct HardwareModel {
    pub name: String,
    /// Model parameters (weights), count.
    pub n_params: f64,
    /// Transformer layer count.
    pub n_layers: f64,
    /// Attention hidden size (q heads * head dim).
    pub d_model: f64,
    /// KV-cache bytes per token (all layers, K+V).
    pub kv_bytes_per_token: f64,
    /// Weight bytes resident in HBM.
    pub weight_bytes: f64,
    /// Peak matmul throughput, FLOP/s (A100 fp16 dense: 312e12).
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// HBM capacity, bytes.
    pub hbm_bytes: f64,
    /// Half-saturation batch size of the MFU curve: efficiency =
    /// tokens / (tokens + mfu_half). Calibrated so chunk 256 runs ~28%
    /// below chunk 2048 throughput (paper Fig. 4).
    pub mfu_half: f64,
    /// Fixed per-iteration overhead, seconds (launch + scheduler).
    pub iteration_overhead_s: f64,
    /// Tensor-parallel degree (adds a per-iteration collective term).
    pub tp_degree: u32,
    /// Per-iteration collective overhead per TP rank pair, seconds.
    pub tp_overhead_s: f64,
}

impl HardwareModel {
    /// Llama3-8B on one A100-80GB (paper's primary setup).
    pub fn llama3_8b_a100() -> Self {
        HardwareModel {
            name: "llama3-8b-a100".into(),
            n_params: 8.0e9,
            n_layers: 32.0,
            d_model: 4096.0,
            // GQA: 8 KV heads * 128 dim * 2 (K+V) * 2 bytes * 32 layers.
            kv_bytes_per_token: 8.0 * 128.0 * 2.0 * 2.0 * 32.0,
            weight_bytes: 16.0e9,
            peak_flops: 312.0e12,
            hbm_bw: 2.0e12,
            hbm_bytes: 80.0e9,
            mfu_half: 120.0,
            iteration_overhead_s: 1.5e-3,
            tp_degree: 1,
            tp_overhead_s: 0.0,
        }
    }

    /// Qwen-7B across two A100s with tensor parallelism (paper's second
    /// setup).
    pub fn qwen_7b_a100_tp2() -> Self {
        HardwareModel {
            name: "qwen-7b-a100-tp2".into(),
            n_params: 7.0e9,
            n_layers: 32.0,
            d_model: 4096.0,
            // MHA: 32 KV heads * 128 dim * 2 * 2 bytes * 32 layers.
            kv_bytes_per_token: 32.0 * 128.0 * 2.0 * 2.0 * 32.0,
            weight_bytes: 14.0e9,
            peak_flops: 2.0 * 312.0e12 * 0.9, // TP efficiency factor
            hbm_bw: 2.0 * 2.0e12,
            hbm_bytes: 2.0 * 80.0e9,
            mfu_half: 150.0,
            iteration_overhead_s: 1.5e-3,
            tp_degree: 2,
            tp_overhead_s: 0.7e-3,
        }
    }

    /// The validation model served by the real PJRT CPU path: the ~7.3M
    /// parameter transformer in `artifacts/`. Constants approximate a
    /// laptop-class CPU; the serving loop refits a predictor from
    /// measured iterations anyway (`runtime::calibrate`).
    pub fn tiny_cpu() -> Self {
        HardwareModel {
            name: "tiny-cpu".into(),
            n_params: 7.3e6,
            n_layers: 4.0,
            d_model: 256.0,
            // 4 KV heads * 32 dim * 2 (K+V) * 4 bytes * 4 layers.
            kv_bytes_per_token: 4.0 * 32.0 * 2.0 * 4.0 * 4.0,
            weight_bytes: 30.0e6,
            peak_flops: 5.0e10,
            hbm_bw: 2.0e10,
            hbm_bytes: 2.0e9,
            mfu_half: 64.0,
            iteration_overhead_s: 10.0e-3,
            tp_degree: 1,
            tp_overhead_s: 0.0,
        }
    }

    /// Look up a built-in preset by name — the one table behind both the
    /// global `hardware.preset` JSON key and per-pool `hardware`
    /// overrides, so the two config surfaces can never drift.
    pub fn preset(name: &str) -> Option<HardwareModel> {
        Some(match name {
            "llama3-8b-a100" => HardwareModel::llama3_8b_a100(),
            "qwen-7b-a100-tp2" => HardwareModel::qwen_7b_a100_tp2(),
            "tiny-cpu" => HardwareModel::tiny_cpu(),
            _ => return None,
        })
    }

    /// KV-cache token capacity after weights + activation reserve.
    pub fn kv_capacity_tokens(&self) -> u64 {
        let reserve = 0.1 * self.hbm_bytes; // activations + fragmentation
        let avail = self.hbm_bytes - self.weight_bytes * self.tp_degree as f64 - reserve;
        (avail.max(0.0) / self.kv_bytes_per_token) as u64
    }
}

/// Scheduling policy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// The paper's system: dynamic chunking + hybrid priority + relegation.
    Niyama,
    /// Sarathi with first-come-first-served prefill order.
    SarathiFcfs,
    /// Sarathi with earliest-deadline-first prefill order.
    SarathiEdf,
    /// Sarathi with shortest-remaining-prompt-first prefill order.
    SarathiSrpf,
    /// Sarathi with shortest-job-first (total estimated work) order.
    SarathiSjf,
}

impl Policy {
    pub fn parse(s: &str) -> Result<Policy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "niyama" => Policy::Niyama,
            "fcfs" | "sarathi-fcfs" => Policy::SarathiFcfs,
            "edf" | "sarathi-edf" => Policy::SarathiEdf,
            "srpf" | "sarathi-srpf" => Policy::SarathiSrpf,
            "sjf" | "sarathi-sjf" => Policy::SarathiSjf,
            other => bail!("unknown policy '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Niyama => "niyama",
            Policy::SarathiFcfs => "sarathi-fcfs",
            Policy::SarathiEdf => "sarathi-edf",
            Policy::SarathiSrpf => "sarathi-srpf",
            Policy::SarathiSjf => "sarathi-sjf",
        }
    }
}

/// Scheduler knobs (paper §3 + §4.4 ablations).
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    pub policy: Policy,
    /// Fixed chunk size for the Sarathi baselines; also Niyama's floor.
    pub chunk_size: u32,
    /// Upper bound for dynamic chunking.
    pub max_chunk_size: u32,
    /// Max decode requests batched per iteration.
    pub max_batch_decodes: usize,
    /// Hybrid-prioritization interpolation factor alpha (eqs. 4-5).
    pub alpha: f64,
    /// Scale alpha with observed load (paper §4.2: "adjusts the alpha
    /// parameter" during overload).
    pub adaptive_alpha: bool,
    /// Ablation switches (Table 3).
    pub dynamic_chunking: bool,
    pub eager_relegation: bool,
    pub hybrid_priority: bool,
    /// Selective preemption of in-prefill requests (paper §3.4).
    pub selective_preemption: bool,
    /// Cap on the fraction of requests that may be relegated (Fig. 5
    /// sweeps this; 1.0 = unlimited).
    pub relegation_cap: f64,
    /// Safety margin subtracted from predicted latency headroom, seconds.
    pub slack_margin_s: f64,
    /// Price scheduling probes by re-evaluating the full batch shape
    /// instead of the O(1) incremental accumulator. Slow — exists only
    /// as the oracle the equivalence tests hold the fast path against;
    /// never enable it in experiments.
    pub reference_costing: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            policy: Policy::Niyama,
            chunk_size: 256,
            max_chunk_size: 2048,
            max_batch_decodes: 256,
            alpha: 0.5,
            adaptive_alpha: true,
            dynamic_chunking: true,
            eager_relegation: true,
            hybrid_priority: true,
            selective_preemption: true,
            relegation_cap: 1.0,
            slack_margin_s: 2.0e-3,
            reference_costing: false,
        }
    }
}

impl SchedulerConfig {
    /// The paper's Sarathi baseline at a given policy: fixed chunks, no
    /// Niyama machinery.
    pub fn sarathi(policy: Policy, chunk_size: u32) -> Self {
        SchedulerConfig {
            policy,
            chunk_size,
            max_chunk_size: chunk_size,
            dynamic_chunking: false,
            eager_relegation: false,
            hybrid_priority: false,
            selective_preemption: false,
            adaptive_alpha: false,
            alpha: 0.0,
            ..SchedulerConfig::default()
        }
    }
}

/// Immutable description of one replica: the hardware it runs on, the
/// scheduler configuration it runs, and which QoS tiers it serves. A
/// replica's spec is fixed from provision to retirement — the cluster
/// never reconfigures a live slot (swap capacity by draining one pool
/// and growing another instead).
#[derive(Debug, Clone)]
pub struct ReplicaSpec {
    pub hardware: HardwareModel,
    pub scheduler: SchedulerConfig,
    /// QoS tier indices this replica serves (empty = every tier). Hard
    /// constraint at dispatch, handoff and drain targeting — unless no
    /// serving replica claims the tier at all, in which case any active
    /// replica may take it so work is never stranded.
    pub tier_affinity: Vec<usize>,
}

impl ReplicaSpec {
    /// The homogeneous spec `Config` has always described: the global
    /// hardware + scheduler, serving every tier.
    pub fn from_config(cfg: &Config) -> Self {
        ReplicaSpec {
            hardware: cfg.hardware.clone(),
            scheduler: cfg.scheduler.clone(),
            tier_affinity: Vec::new(),
        }
    }

    /// Affinity as a bitmask over tier indices (0 = serves every tier),
    /// the form `LoadSnapshot` carries so dispatch policies can check it
    /// without an allocation.
    pub fn affinity_mask(&self) -> u32 {
        let mut mask = 0u32;
        for &t in &self.tier_affinity {
            mask |= 1 << t.min(31);
        }
        mask
    }

    /// The engine configuration for one replica of this spec: the
    /// cluster-shared base (tiers, seed, dispatch/control plane) with
    /// this spec's hardware and scheduler substituted.
    pub fn engine_config(&self, base: &Config) -> Config {
        let mut cfg = base.clone();
        cfg.hardware = self.hardware.clone();
        cfg.scheduler = self.scheduler.clone();
        cfg
    }
}

/// One replica pool: a spec, how many replicas it starts with, and the
/// bounds the autoscaler may move it between.
#[derive(Debug, Clone)]
pub struct PoolSpec {
    pub name: String,
    pub spec: ReplicaSpec,
    /// Replicas provisioned at construction.
    pub replicas: usize,
    /// Autoscale floor for this pool (0 = the pool may drain empty while
    /// other pools keep the cluster serviceable).
    pub min_replicas: usize,
    /// Autoscale ceiling for this pool.
    pub max_replicas: usize,
    /// This pool's interconnect attachment for live KV migration
    /// (`None` = inherit `cluster.interconnect`). A transfer between two
    /// pools is priced at the bottleneck of the two attachments: the
    /// lower bandwidth, the higher latency. A pool whose effective
    /// bandwidth is zero/absent neither sends nor receives live
    /// migrations.
    pub interconnect: Option<InterconnectConfig>,
}

impl PoolSpec {
    /// A static pool: `replicas` instances of `spec`, never scaled,
    /// inheriting the cluster-level interconnect.
    pub fn fixed(name: &str, spec: ReplicaSpec, replicas: usize) -> Self {
        PoolSpec {
            name: name.to_string(),
            spec,
            replicas,
            min_replicas: replicas,
            max_replicas: replicas,
            interconnect: None,
        }
    }
}

/// Cluster topology as a set of replica pools behind one dispatcher.
/// The old single-`Config`-times-N constructor is the one-pool special
/// case ([`ClusterSpec::homogeneous`]); a siloed deployment is pools
/// with disjoint tier affinities behind tier-affinity dispatch.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub pools: Vec<PoolSpec>,
}

impl ClusterSpec {
    /// The compatibility shim: one pool of `replicas` identical engines
    /// built from the global config, bounded by the control-plane
    /// min/max. `Cluster::new(&cfg, n)` is exactly this spec.
    pub fn homogeneous(cfg: &Config, replicas: usize) -> Self {
        ClusterSpec {
            pools: vec![PoolSpec {
                name: "pool0".to_string(),
                spec: ReplicaSpec::from_config(cfg),
                replicas,
                min_replicas: cfg.cluster.control.min_replicas,
                max_replicas: cfg.cluster.control.max_replicas,
                interconnect: None,
            }],
        }
    }

    pub fn total_replicas(&self) -> usize {
        self.pools.iter().map(|p| p.replicas).sum()
    }

    /// The spec randomized/predictive dispatchers calibrate against
    /// (pool 0 — for the homogeneous shim this is the global config).
    pub fn reference_spec(&self) -> &ReplicaSpec {
        &self.pools[0].spec
    }

    pub fn validate(&self, n_tiers: usize) -> Result<()> {
        if self.pools.is_empty() {
            bail!("cluster spec needs at least one pool");
        }
        if self.total_replicas() == 0 {
            bail!("cluster spec needs at least one initial replica across its pools");
        }
        let mut names = std::collections::HashSet::new();
        for p in &self.pools {
            if p.name.is_empty() {
                bail!("pool names must be non-empty");
            }
            if !names.insert(p.name.as_str()) {
                bail!("duplicate pool name '{}'", p.name);
            }
            if p.max_replicas < p.min_replicas {
                bail!("pool '{}': max_replicas must be >= min_replicas", p.name);
            }
            // `replicas > max_replicas` is deliberately legal: a pool may
            // start above its autoscale ceiling (static over-provisioned
            // deployments); the controller simply never grows it further.
            if p.spec.scheduler.chunk_size == 0 {
                bail!("pool '{}': chunk_size must be positive", p.name);
            }
            if p.spec.scheduler.max_chunk_size < p.spec.scheduler.chunk_size {
                bail!("pool '{}': max_chunk_size must be >= chunk_size", p.name);
            }
            if let Some(ic) = &p.interconnect {
                ic.validate(&format!("pool '{}': interconnect", p.name))?;
            }
            for &t in &p.spec.tier_affinity {
                // Affinity indices must name real tiers — the old silo
                // sizing silently indexed `cfg.tiers[tier]` and could
                // drift or panic out of range.
                if t >= n_tiers {
                    bail!(
                        "pool '{}': tier_affinity {t} out of range (have {n_tiers} tiers)",
                        p.name
                    );
                }
                if t >= 32 {
                    bail!("pool '{}': tier_affinity indices must be < 32", p.name);
                }
            }
        }
        Ok(())
    }
}

/// Global dispatch policy: how the cluster front-end routes each arrival
/// to a replica (see `simulator::dispatch`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Stateless rotation — the seed's behavior and the standard
    /// load-oblivious front-end baseline.
    RoundRobin,
    /// Route to the replica with the fewest requests awaiting prefill.
    JoinShortestQueue,
    /// QoS/slack-aware: route on live load snapshots (queued prefill
    /// seconds, KV pressure, per-tier slack headroom), preferring
    /// replicas that can still meet the arrival's deadline.
    LeastLoaded,
    /// Sample two random replicas, route to the lower-pressure one
    /// (the `LeastLoaded` score on just the pair). O(1) per arrival
    /// regardless of replica count — the classic balanced-allocations
    /// result keeps the max load within O(log log R) of optimal.
    PowerOfTwoChoices,
    /// Power-of-two-choices sampling scored by the fitted per-replica
    /// latency predictor instead of `LeastLoaded`'s linear token rate:
    /// the predicted TTFT accounts for the candidate's live decode load
    /// inflating every prefill chunk it would serve ahead of this
    /// arrival.
    PredictedTtft,
    /// Route each arrival round-robin among the replicas whose
    /// tier-affinity claims its tier, with an independent rotation per
    /// tier — a siloed deployment expressed as dispatch policy over
    /// affinity-tagged pools (`run_silo` is built on this).
    TierAffinity,
    /// Prefix-cache-aware routing for session workloads: score each
    /// replica's queue wait plus the cheapest way to acquire the turn's
    /// session prefix there — reuse its own cached prefix, re-prefill
    /// the miss, or (when an interconnect is configured) ship the best
    /// cached prefix over it. Falls back to `LeastLoaded`-style scoring
    /// for sessionless arrivals.
    CacheAffinity,
}

impl DispatchPolicy {
    pub fn parse(s: &str) -> Result<DispatchPolicy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => DispatchPolicy::RoundRobin,
            "join-shortest-queue" | "jsq" => DispatchPolicy::JoinShortestQueue,
            "least-loaded" | "ll" => DispatchPolicy::LeastLoaded,
            "power-of-two-choices" | "p2c" => DispatchPolicy::PowerOfTwoChoices,
            "predicted-ttft" | "pttft" => DispatchPolicy::PredictedTtft,
            "tier-affinity" | "silo" => DispatchPolicy::TierAffinity,
            "cache-affinity" | "ca" => DispatchPolicy::CacheAffinity,
            other => bail!("unknown dispatch policy '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round-robin",
            DispatchPolicy::JoinShortestQueue => "join-shortest-queue",
            DispatchPolicy::LeastLoaded => "least-loaded",
            DispatchPolicy::PowerOfTwoChoices => "power-of-two-choices",
            DispatchPolicy::PredictedTtft => "predicted-ttft",
            DispatchPolicy::TierAffinity => "tier-affinity",
            DispatchPolicy::CacheAffinity => "cache-affinity",
        }
    }
}

/// Cluster dispatch knobs.
#[derive(Debug, Clone)]
pub struct DispatchConfig {
    pub policy: DispatchPolicy,
    /// Llumnix-style cross-replica relegation handoff: requests a replica
    /// relegates may be re-dispatched to a replica with spare headroom.
    pub relegation_handoff: bool,
    /// Seed for randomized policies (power-of-two-choices sampling);
    /// runs are bit-reproducible for a fixed seed.
    pub seed: u64,
}

impl Default for DispatchConfig {
    fn default() -> Self {
        // Round-robin without handoff reproduces the seed's static shard
        // split exactly, so existing experiments are unchanged by default.
        DispatchConfig { policy: DispatchPolicy::RoundRobin, relegation_handoff: false, seed: 0 }
    }
}

/// Interconnect between replicas, the price model live KV migration
/// runs on (see `simulator::migration`): moving a request whose KV
/// occupies `B` bytes costs `B / bandwidth + latency` seconds of
/// virtual time, during which the KV occupies both replicas. Configured
/// under `cluster.interconnect`; when absent — or with zero bandwidth —
/// live migration is disabled and every timeline is bit-for-bit the
/// handoff-only one.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InterconnectConfig {
    /// Usable cross-replica bandwidth, decimal gigabytes per second.
    /// Defaults to a PCIe/InfiniBand-class 25 GB/s; zero disables live
    /// migration.
    pub bandwidth_gbytes_per_s: f64,
    /// Fixed per-transfer setup latency, seconds.
    pub latency_s: f64,
}

impl Default for InterconnectConfig {
    fn default() -> Self {
        InterconnectConfig { bandwidth_gbytes_per_s: 25.0, latency_s: 1e-3 }
    }
}

impl InterconnectConfig {
    /// Parse a JSON `interconnect` object: defaults from
    /// [`InterconnectConfig::default`], overridden per key. The one
    /// parser behind both the cluster-level and per-pool surfaces, so
    /// the two can never drift.
    fn from_json(j: &Json) -> InterconnectConfig {
        let mut k = InterconnectConfig::default();
        override_f64(j, "bandwidth_gbytes_per_s", &mut k.bandwidth_gbytes_per_s);
        override_f64(j, "latency_s", &mut k.latency_s);
        k
    }

    /// Range-check both fields; `what` names the config surface in the
    /// error (NaN fails both comparisons and is rejected too). Shared by
    /// `Config::validate` and `ClusterSpec::validate`.
    fn validate(&self, what: &str) -> Result<()> {
        if self.bandwidth_gbytes_per_s.is_nan() || self.bandwidth_gbytes_per_s < 0.0 {
            bail!("{what}.bandwidth_gbytes_per_s must be >= 0 (0 disables live migration)");
        }
        if self.latency_s.is_nan() || self.latency_s < 0.0 {
            bail!("{what}.latency_s must be non-negative");
        }
        Ok(())
    }
}

/// Per-replica prefix cache over retained session KV (see
/// [`crate::kv::PrefixCache`]). Configured under `cluster.prefix_cache`;
/// when absent the cache does not exist and every timeline is
/// bit-for-bit the session-oblivious one.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefixCacheConfig {
    /// Fraction of each replica's KV capacity the cache may occupy.
    /// Residency is strictly subordinate to live requests: the engine
    /// evicts down to the live-KV headroom every step, so this is a cap,
    /// not a reservation.
    pub capacity_frac: f64,
    /// Cache block granularity, tokens: hits are floored to whole blocks
    /// and residency is charged block-rounded (vLLM-style paging).
    pub block_tokens: u32,
}

impl Default for PrefixCacheConfig {
    fn default() -> Self {
        PrefixCacheConfig { capacity_frac: 0.2, block_tokens: 64 }
    }
}

impl PrefixCacheConfig {
    /// Parse a JSON `prefix_cache` object: defaults from
    /// [`PrefixCacheConfig::default`], overridden per key.
    fn from_json(j: &Json) -> Result<PrefixCacheConfig> {
        let mut k = PrefixCacheConfig::default();
        override_f64(j, "capacity_frac", &mut k.capacity_frac);
        override_u32(j, "block_tokens", &mut k.block_tokens)?;
        Ok(k)
    }

    fn validate(&self, what: &str) -> Result<()> {
        if self.capacity_frac.is_nan()
            || self.capacity_frac <= 0.0
            || self.capacity_frac > 1.0
        {
            bail!("{what}.capacity_frac must be in (0, 1]");
        }
        if self.block_tokens == 0 {
            bail!("{what}.block_tokens must be at least 1");
        }
        Ok(())
    }
}

/// Multi-turn session workload shape, layered over a dataset's
/// prompt/decode statistics (see `workload::SessionSpec`). Configured
/// under `workload.session`; absence keeps the single-shot generator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SessionConfig {
    /// Mean turns per session (geometric distribution, min 1).
    pub mean_turns: f64,
    /// Mean think time between a turn finishing and the next being sent
    /// (exponential), seconds.
    pub mean_think_s: f64,
    /// Fraction of sessions that belong to the flash crowd: they all
    /// share one hot system prompt (session id 0), so a single retained
    /// prefix serves many users.
    pub flash_frac: f64,
    /// Token length of the shared hot system prompt.
    pub hot_prompt_tokens: u32,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            mean_turns: 4.0,
            mean_think_s: 10.0,
            flash_frac: 0.0,
            hot_prompt_tokens: 1024,
        }
    }
}

impl SessionConfig {
    /// Parse a JSON `session` object: defaults from
    /// [`SessionConfig::default`], overridden per key.
    fn from_json(j: &Json) -> Result<SessionConfig> {
        let mut k = SessionConfig::default();
        override_f64(j, "mean_turns", &mut k.mean_turns);
        override_f64(j, "mean_think_s", &mut k.mean_think_s);
        override_f64(j, "flash_frac", &mut k.flash_frac);
        override_u32(j, "hot_prompt_tokens", &mut k.hot_prompt_tokens)?;
        Ok(k)
    }

    fn validate(&self, what: &str) -> Result<()> {
        if self.mean_turns.is_nan() || self.mean_turns < 1.0 {
            bail!("{what}.mean_turns must be at least 1");
        }
        if self.mean_think_s.is_nan() || self.mean_think_s < 0.0 {
            bail!("{what}.mean_think_s must be non-negative");
        }
        if self.flash_frac.is_nan() || !(0.0..=1.0).contains(&self.flash_frac) {
            bail!("{what}.flash_frac must be in [0, 1]");
        }
        if self.flash_frac > 0.0 && self.hot_prompt_tokens == 0 {
            bail!("{what}.hot_prompt_tokens must be positive when flash_frac > 0");
        }
        Ok(())
    }
}

/// Sharded-execution knobs for the cluster event loop (see
/// `simulator::parallel`). Configured under `cluster.parallel`; when the
/// block is absent the `NIYAMA_WORKERS` environment variable supplies
/// the default, and `workers: 1` (or no override at all) selects the
/// sequential event loop — the bit-for-bit oracle the sharded path is
/// pinned against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelConfig {
    /// Worker threads the engines are striped across (replica `i` lives
    /// on shard `i % workers`). Must be >= 1; 1 means sequential.
    pub workers: usize,
}

impl ParallelConfig {
    /// Parse a JSON `parallel` object (`{"workers": N}`).
    fn from_json(j: &Json) -> Result<ParallelConfig> {
        let mut k = ParallelConfig { workers: 1 };
        if let Some(v) = j.get("workers").and_then(|v| v.as_usize()) {
            k.workers = v;
        }
        Ok(k)
    }

    fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            bail!("cluster.parallel.workers must be at least 1 (1 = sequential)");
        }
        Ok(())
    }
}

/// Flight-recorder knobs (see [`crate::obs`]). Configured under
/// `cluster.observability`; when the block is absent no trace buffers
/// exist, every hook is a null-pointer check, and the simulation output
/// is bit-for-bit the unobserved system. The SLO autopsy in `Summary`
/// is always computed — it is summary-time reporting, not simulation
/// state — so this block only governs event tracing and the time-series
/// sampler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ObservabilityConfig {
    /// Record per-request lifecycle events for Chrome-trace/Perfetto
    /// export.
    pub trace: bool,
    /// Sample per-control-tick cluster gauges for JSONL export.
    pub series: bool,
}

impl Default for ObservabilityConfig {
    fn default() -> Self {
        ObservabilityConfig { trace: true, series: true }
    }
}

impl ObservabilityConfig {
    /// Parse a JSON `observability` object: both recorders default on
    /// when the block is present, overridden per key.
    fn from_json(j: &Json) -> Result<ObservabilityConfig> {
        let mut k = ObservabilityConfig::default();
        override_bool(j, "trace", &mut k.trace);
        override_bool(j, "series", &mut k.series);
        Ok(k)
    }

    fn validate(&self, what: &str) -> Result<()> {
        if !self.trace && !self.series {
            bail!("{what} enables neither trace nor series — drop the block instead");
        }
        Ok(())
    }
}

/// Runtime invariant auditor knob (see [`crate::audit`]). Configured
/// under `cluster.audit`; when the block is absent the `NIYAMA_AUDIT`
/// environment variable decides, and the default is off. The auditor
/// only reads coordinator state and panics on violation, so an audited
/// run's output is bit-for-bit the unaudited run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AuditConfig {
    /// Check the cluster invariants at every coordinator barrier.
    pub enabled: bool,
}

impl Default for AuditConfig {
    fn default() -> Self {
        AuditConfig { enabled: true }
    }
}

impl AuditConfig {
    /// Parse a JSON `audit` object: present means on, overridden per
    /// key (`{"enabled": false}` pins the auditor off even under
    /// `NIYAMA_AUDIT=1`).
    fn from_json(j: &Json) -> Result<AuditConfig> {
        let mut k = AuditConfig::default();
        override_bool(j, "enabled", &mut k.enabled);
        Ok(k)
    }
}

/// Wall-clock profiler knob (see [`crate::obs::prof`]). Configured
/// under `cluster.profiling`; when the block is absent the
/// `NIYAMA_PROF` environment variable decides, and the default is off.
/// The profiler only reads the wall clock and aggregates it for export
/// — never a simulation input — so a profiled run's output is
/// bit-for-bit the unprofiled run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProfilingConfig {
    /// Record per-superstep wall times and the coordinator phase
    /// breakdown (exported via `Cluster::profile_json` and friends).
    pub enabled: bool,
}

impl Default for ProfilingConfig {
    fn default() -> Self {
        ProfilingConfig { enabled: true }
    }
}

impl ProfilingConfig {
    /// Parse a JSON `profiling` object: present means on, overridden per
    /// key (`{"enabled": false}` pins the profiler off even under
    /// `NIYAMA_PROF=1`).
    fn from_json(j: &Json) -> Result<ProfilingConfig> {
        let mut k = ProfilingConfig::default();
        override_bool(j, "enabled", &mut k.enabled);
        Ok(k)
    }
}

/// Elastic control-plane policy selector (see `simulator::control`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AutoscalePolicy {
    /// Static replica set — the pre-control-plane behavior.
    Off,
    /// Hysteresis on queued-prefill-seconds per replica / KV pressure:
    /// scale when the signal stays past a watermark for `hold_s`.
    Reactive,
    /// Tier-slack-aware predictive control: project queue growth over
    /// the warm-up horizon and order capacity before the strictest
    /// tier's slack is exhausted.
    Predictive,
}

impl AutoscalePolicy {
    pub fn parse(s: &str) -> Result<AutoscalePolicy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "off" | "none" | "static" => AutoscalePolicy::Off,
            "reactive" | "hysteresis" => AutoscalePolicy::Reactive,
            "predictive" | "tier-slack" => AutoscalePolicy::Predictive,
            other => bail!("unknown autoscale policy '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AutoscalePolicy::Off => "off",
            AutoscalePolicy::Reactive => "reactive",
            AutoscalePolicy::Predictive => "predictive",
        }
    }
}

/// Elastic control-plane knobs: autoscaler bounds and signals plus the
/// global admission policy applied at the dispatcher.
#[derive(Debug, Clone)]
pub struct ControlConfig {
    pub autoscale: AutoscalePolicy,
    /// Lower bound on serving (active + warming) replicas.
    pub min_replicas: usize,
    /// Upper bound on serving replicas.
    pub max_replicas: usize,
    /// Cold-start seconds between provisioning a replica and the engine
    /// accepting work.
    pub warmup_s: f64,
    /// Controller evaluation period on the shared virtual clock.
    pub control_interval_s: f64,
    /// Scale-up watermark: queued prefill seconds per serving replica.
    pub scale_up_queue_s: f64,
    /// Scale-down watermark (must not exceed the scale-up watermark).
    pub scale_down_queue_s: f64,
    /// How long a watermark must hold before the controller acts.
    pub hold_s: f64,
    /// Global admission control applied to every arrival at dispatch.
    pub admission: crate::simulator::dispatch::AdmissionPolicy,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            autoscale: AutoscalePolicy::Off,
            min_replicas: 1,
            max_replicas: 8,
            warmup_s: 20.0,
            control_interval_s: 5.0,
            scale_up_queue_s: 4.0,
            scale_down_queue_s: 0.5,
            hold_s: 10.0,
            admission: crate::simulator::dispatch::AdmissionPolicy::None,
        }
    }
}

/// Cluster topology for multi-replica serving / silo experiments.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of identical replicas sharing the workload (the one-pool
    /// layout; ignored when `pools` is non-empty).
    pub replicas: usize,
    /// Heterogeneous replica pools (empty = one homogeneous pool of
    /// `replicas` engines built from the global hardware + scheduler).
    pub pools: Vec<PoolSpec>,
    /// How arrivals are routed across those replicas.
    pub dispatch: DispatchConfig,
    /// Elastic control plane: autoscaling + admission control.
    pub control: ControlConfig,
    /// Cross-replica interconnect for live KV migration (`None` — the
    /// default — keeps the handoff-only behavior bit-for-bit).
    pub interconnect: Option<InterconnectConfig>,
    /// Per-replica prefix cache over retained session KV (`None` — the
    /// default — keeps the session-oblivious behavior bit-for-bit).
    pub prefix_cache: Option<PrefixCacheConfig>,
    /// Sharded cluster-loop execution (`None` = the `NIYAMA_WORKERS`
    /// env default, falling back to the sequential loop).
    pub parallel: Option<ParallelConfig>,
    /// Flight recorder: lifecycle tracing + time-series sampling
    /// (`None` — the default — records nothing and keeps the hot path
    /// untouched).
    pub observability: Option<ObservabilityConfig>,
    /// Runtime invariant auditor (`None` = the `NIYAMA_AUDIT` env
    /// default, falling back to off).
    pub audit: Option<AuditConfig>,
    /// Wall-clock profiler (`None` = the `NIYAMA_PROF` env default,
    /// falling back to off).
    pub profiling: Option<ProfilingConfig>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: 1,
            pools: Vec::new(),
            dispatch: DispatchConfig::default(),
            control: ControlConfig::default(),
            interconnect: None,
            prefix_cache: None,
            parallel: None,
            observability: None,
            audit: None,
            profiling: None,
        }
    }
}

impl ClusterConfig {
    /// Effective worker-thread count for the cluster event loop: the
    /// explicit `parallel` block when present, else the `NIYAMA_WORKERS`
    /// environment override (the CI matrix leg), else 1 — the sequential
    /// path. Unparseable or zero env values fall back to 1 rather than
    /// failing a run that never asked for sharding.
    pub fn effective_workers(&self) -> usize {
        if let Some(p) = &self.parallel {
            return p.workers.max(1);
        }
        std::env::var("NIYAMA_WORKERS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .map_or(1, |w| w.max(1))
    }

    /// Whether the runtime invariant auditor runs: the explicit `audit`
    /// block when present (so a config can pin it on *or* off), else the
    /// `NIYAMA_AUDIT` environment override (the CI matrix leg), else
    /// off. Anything but `1`/`true` in the env counts as off.
    pub fn effective_audit(&self) -> bool {
        if let Some(a) = &self.audit {
            return a.enabled;
        }
        std::env::var("NIYAMA_AUDIT")
            .map(|v| matches!(v.trim(), "1" | "true"))
            .unwrap_or(false)
    }

    /// Whether the wall-clock profiler runs: the explicit `profiling`
    /// block when present (so a config can pin it on *or* off), else the
    /// `NIYAMA_PROF` environment override, else off. Anything but
    /// `1`/`true` in the env counts as off. Same precedence as
    /// [`ClusterConfig::effective_audit`].
    pub fn effective_profiling(&self) -> bool {
        if let Some(p) = &self.profiling {
            return p.enabled;
        }
        std::env::var("NIYAMA_PROF")
            .map(|v| matches!(v.trim(), "1" | "true"))
            .unwrap_or(false)
    }
}

/// Top-level system configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub hardware: HardwareModel,
    pub scheduler: SchedulerConfig,
    pub tiers: Vec<QosTier>,
    pub cluster: ClusterConfig,
    /// Multi-turn session workload shape (`workload.session` in JSON;
    /// `None` keeps the single-shot generator). Consumed by
    /// `workload::SessionSpec::from_config`.
    pub session: Option<SessionConfig>,
    /// Random seed for workload generation.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            hardware: HardwareModel::llama3_8b_a100(),
            scheduler: SchedulerConfig::default(),
            tiers: table2_tiers(),
            cluster: ClusterConfig::default(),
            session: None,
            seed: 0,
        }
    }
}

impl Config {
    /// Load a config from a JSON file; unspecified fields keep defaults.
    pub fn from_file(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::from_json_str(&text)
    }

    pub fn from_json_str(text: &str) -> Result<Config> {
        let j = Json::parse(text).map_err(|e| anyhow!("config parse: {e}"))?;
        let mut cfg = Config::default();

        if let Some(hw) = j.get("hardware") {
            if let Some(name) = hw.get("preset").and_then(|v| v.as_str()) {
                cfg.hardware = HardwareModel::preset(name)
                    .ok_or_else(|| anyhow!("unknown hardware preset '{name}'"))?;
            }
            override_f64(hw, "peak_flops", &mut cfg.hardware.peak_flops);
            override_f64(hw, "hbm_bw", &mut cfg.hardware.hbm_bw);
            override_f64(hw, "hbm_bytes", &mut cfg.hardware.hbm_bytes);
            override_f64(hw, "mfu_half", &mut cfg.hardware.mfu_half);
            override_f64(hw, "iteration_overhead_s", &mut cfg.hardware.iteration_overhead_s);
        }

        if let Some(s) = j.get("scheduler") {
            if let Some(p) = s.get("policy").and_then(|v| v.as_str()) {
                cfg.scheduler.policy = Policy::parse(p)?;
            }
            override_u32(s, "chunk_size", &mut cfg.scheduler.chunk_size)?;
            override_u32(s, "max_chunk_size", &mut cfg.scheduler.max_chunk_size)?;
            override_f64(s, "alpha", &mut cfg.scheduler.alpha);
            override_f64(s, "relegation_cap", &mut cfg.scheduler.relegation_cap);
            override_bool(s, "dynamic_chunking", &mut cfg.scheduler.dynamic_chunking);
            override_bool(s, "eager_relegation", &mut cfg.scheduler.eager_relegation);
            override_bool(s, "hybrid_priority", &mut cfg.scheduler.hybrid_priority);
            override_bool(s, "adaptive_alpha", &mut cfg.scheduler.adaptive_alpha);
            override_bool(s, "selective_preemption", &mut cfg.scheduler.selective_preemption);
            if let Some(v) = s.get("max_batch_decodes").and_then(|v| v.as_usize()) {
                cfg.scheduler.max_batch_decodes = v;
            }
        }

        if let Some(tiers) = j.get("tiers").and_then(|v| v.as_arr()) {
            cfg.tiers = tiers.iter().map(parse_tier).collect::<Result<_>>()?;
        }

        if let Some(c) = j.get("cluster") {
            if let Some(v) = c.get("replicas").and_then(|v| v.as_usize()) {
                cfg.cluster.replicas = v;
            }
            if let Some(pools) = c.get("pools").and_then(|v| v.as_arr()) {
                let parsed: Vec<PoolSpec> =
                    pools.iter().map(|p| parse_pool(p, &cfg)).collect::<Result<_>>()?;
                cfg.cluster.pools = parsed;
            }
            if let Some(p) = c.get("dispatch").and_then(|v| v.as_str()) {
                cfg.cluster.dispatch.policy = DispatchPolicy::parse(p)?;
            }
            override_bool(c, "relegation_handoff", &mut cfg.cluster.dispatch.relegation_handoff);
            if let Some(v) = c.get("dispatch_seed").and_then(|v| v.as_f64()) {
                cfg.cluster.dispatch.seed = v as u64;
            }
            if let Some(ic) = c.get("interconnect") {
                cfg.cluster.interconnect = Some(InterconnectConfig::from_json(ic));
            }
            if let Some(pc) = c.get("prefix_cache") {
                cfg.cluster.prefix_cache = Some(PrefixCacheConfig::from_json(pc)?);
            }
            if let Some(par) = c.get("parallel") {
                cfg.cluster.parallel = Some(ParallelConfig::from_json(par)?);
            }
            if let Some(o) = c.get("observability") {
                cfg.cluster.observability = Some(ObservabilityConfig::from_json(o)?);
            }
            if let Some(a) = c.get("audit") {
                cfg.cluster.audit = Some(AuditConfig::from_json(a)?);
            }
            if let Some(p) = c.get("profiling") {
                cfg.cluster.profiling = Some(ProfilingConfig::from_json(p)?);
            }
            if let Some(ctl) = c.get("control") {
                // With pools configured, autoscale bounds live on the
                // pools (the control-level ones only seed the one-pool
                // homogeneous layout); accepting both silently would let
                // an operator set a cluster-wide cap that does nothing.
                if !cfg.cluster.pools.is_empty()
                    && (ctl.get("min_replicas").is_some() || ctl.get("max_replicas").is_some())
                {
                    bail!(
                        "cluster.control.min_replicas/max_replicas are ignored when \
                         cluster.pools is set — give each pool its own \
                         min_replicas/max_replicas instead"
                    );
                }
                let k = &mut cfg.cluster.control;
                if let Some(p) = ctl.get("autoscale").and_then(|v| v.as_str()) {
                    k.autoscale = AutoscalePolicy::parse(p)?;
                }
                if let Some(v) = ctl.get("min_replicas").and_then(|v| v.as_usize()) {
                    k.min_replicas = v;
                }
                if let Some(v) = ctl.get("max_replicas").and_then(|v| v.as_usize()) {
                    k.max_replicas = v;
                }
                override_f64(ctl, "warmup_s", &mut k.warmup_s);
                override_f64(ctl, "control_interval_s", &mut k.control_interval_s);
                override_f64(ctl, "scale_up_queue_s", &mut k.scale_up_queue_s);
                override_f64(ctl, "scale_down_queue_s", &mut k.scale_down_queue_s);
                override_f64(ctl, "hold_s", &mut k.hold_s);
                if let Some(p) = ctl.get("admission").and_then(|v| v.as_str()) {
                    k.admission = crate::simulator::dispatch::AdmissionPolicy::parse(p)?;
                }
            }
        }

        if let Some(w) = j.get("workload") {
            if let Some(s) = w.get("session") {
                cfg.session = Some(SessionConfig::from_json(s)?);
            }
        }

        if let Some(v) = j.get("seed").and_then(|v| v.as_f64()) {
            cfg.seed = v as u64;
        }

        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.tiers.is_empty() {
            bail!("at least one QoS tier is required");
        }
        if self.scheduler.chunk_size == 0 {
            bail!("chunk_size must be positive");
        }
        if self.scheduler.max_chunk_size < self.scheduler.chunk_size {
            bail!("max_chunk_size must be >= chunk_size");
        }
        if !(0.0..=1.0).contains(&self.scheduler.relegation_cap) {
            bail!("relegation_cap must be in [0, 1]");
        }
        if self.cluster.replicas == 0 {
            bail!("cluster needs at least one replica");
        }
        let k = &self.cluster.control;
        if k.min_replicas == 0 {
            bail!("control.min_replicas must be at least 1");
        }
        if k.max_replicas < k.min_replicas {
            bail!("control.max_replicas must be >= control.min_replicas");
        }
        if k.control_interval_s <= 0.0 {
            bail!("control.control_interval_s must be positive");
        }
        if k.warmup_s < 0.0 {
            bail!("control.warmup_s must be non-negative");
        }
        if k.scale_down_queue_s > k.scale_up_queue_s {
            bail!("control.scale_down_queue_s must not exceed scale_up_queue_s");
        }
        if let Some(ic) = &self.cluster.interconnect {
            ic.validate("cluster.interconnect")?;
        }
        if let Some(pc) = &self.cluster.prefix_cache {
            pc.validate("cluster.prefix_cache")?;
        }
        if let Some(s) = &self.session {
            s.validate("workload.session")?;
        }
        if let Some(par) = &self.cluster.parallel {
            par.validate()?;
        }
        if let Some(o) = &self.cluster.observability {
            o.validate("cluster.observability")?;
        }
        if !self.cluster.pools.is_empty() {
            self.cluster_spec().validate(self.tiers.len())?;
        }
        Ok(())
    }

    /// The cluster topology this config describes: the configured pools,
    /// or the one-pool homogeneous layout of `cluster.replicas` engines.
    pub fn cluster_spec(&self) -> ClusterSpec {
        if self.cluster.pools.is_empty() {
            ClusterSpec::homogeneous(self, self.cluster.replicas)
        } else {
            ClusterSpec { pools: self.cluster.pools.clone() }
        }
    }
}

/// Parse one entry of the cluster `pools` array. Hardware and scheduler
/// default to the global config's; `policy`, `chunk_size`,
/// `max_chunk_size`, `hardware` (preset name) and `tier_affinity`
/// override per pool.
fn parse_pool(j: &Json, base: &Config) -> Result<PoolSpec> {
    let name = j
        .get("name")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("pool missing 'name'"))?
        .to_string();
    let mut hardware = base.hardware.clone();
    if let Some(h) = j.get("hardware").and_then(|v| v.as_str()) {
        hardware = HardwareModel::preset(h)
            .ok_or_else(|| anyhow!("pool '{name}': unknown hardware preset '{h}'"))?;
    }
    let chunk = j.get("chunk_size").and_then(|v| v.as_usize()).map(|v| v as u32);
    let mut scheduler = match j.get("policy").and_then(|v| v.as_str()) {
        Some(p) => {
            let policy = Policy::parse(p)?;
            if policy == Policy::Niyama {
                let mut s = base.scheduler.clone();
                s.policy = policy;
                s
            } else {
                // Sarathi pools get the full baseline preset (fixed
                // chunk, no Niyama machinery) at the requested chunk.
                SchedulerConfig::sarathi(policy, chunk.unwrap_or(base.scheduler.chunk_size))
            }
        }
        None => base.scheduler.clone(),
    };
    if let Some(c) = chunk {
        scheduler.chunk_size = c;
        scheduler.max_chunk_size = scheduler.max_chunk_size.max(c);
    }
    override_u32(j, "max_chunk_size", &mut scheduler.max_chunk_size)?;
    let mut tier_affinity = Vec::new();
    if let Some(arr) = j.get("tier_affinity").and_then(|v| v.as_arr()) {
        for t in arr {
            let t = t
                .as_usize()
                .ok_or_else(|| anyhow!("pool '{name}': tier_affinity entries must be tier indices"))?;
            tier_affinity.push(t);
        }
    }
    let replicas = j.get("replicas").and_then(|v| v.as_usize()).unwrap_or(1);
    // Bounds default to the initial size: a pool is static unless the
    // config opts it into autoscaling with explicit min/max.
    let min_replicas = j.get("min_replicas").and_then(|v| v.as_usize()).unwrap_or(replicas);
    let max_replicas = j.get("max_replicas").and_then(|v| v.as_usize()).unwrap_or(replicas);
    // Per-pool interconnect attachment; absence inherits the
    // cluster-level setting.
    let interconnect = j.get("interconnect").map(InterconnectConfig::from_json);
    Ok(PoolSpec {
        name,
        spec: ReplicaSpec { hardware, scheduler, tier_affinity },
        replicas,
        min_replicas,
        max_replicas,
        interconnect,
    })
}

fn parse_tier(j: &Json) -> Result<QosTier> {
    let name = j
        .get("name")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("tier missing 'name'"))?;
    let slo = if let Some(ttlt) = j.get("ttlt_s").and_then(|v| v.as_f64()) {
        Slo::NonInteractive { ttlt_s: ttlt }
    } else {
        let ttft = j
            .get("ttft_s")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("tier '{name}' needs ttft_s+tbt_s or ttlt_s"))?;
        let tbt = j
            .get("tbt_s")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("tier '{name}' needs tbt_s"))?;
        Slo::Interactive { ttft_s: ttft, tbt_s: tbt }
    };
    Ok(QosTier { name: name.to_string(), slo })
}

fn override_f64(j: &Json, key: &str, slot: &mut f64) {
    if let Some(v) = j.get(key).and_then(|v| v.as_f64()) {
        *slot = v;
    }
}

fn override_u32(j: &Json, key: &str, slot: &mut u32) -> Result<()> {
    if let Some(v) = j.get(key) {
        let n = v.as_usize().ok_or_else(|| anyhow!("'{key}' must be a non-negative integer"))?;
        *slot = n as u32;
    }
    Ok(())
}

fn override_bool(j: &Json, key: &str, slot: &mut bool) {
    if let Some(v) = j.get(key).and_then(|v| v.as_bool()) {
        *slot = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_setup() {
        let c = Config::default();
        assert_eq!(c.tiers.len(), 3);
        assert_eq!(c.scheduler.policy, Policy::Niyama);
        assert_eq!(c.scheduler.chunk_size, 256);
        assert_eq!(c.hardware.name, "llama3-8b-a100");
        c.validate().unwrap();
    }

    #[test]
    fn kv_capacity_reasonable_for_a100() {
        let hw = HardwareModel::llama3_8b_a100();
        let cap = hw.kv_capacity_tokens();
        // ~(80 - 16 - 8) GB / 131 KB ≈ 430k tokens.
        assert!(cap > 300_000 && cap < 600_000, "capacity {cap}");
    }

    #[test]
    fn json_overrides() {
        let c = Config::from_json_str(
            r#"{
                "scheduler": {"policy": "sarathi-edf", "chunk_size": 128,
                              "dynamic_chunking": false, "alpha": 0.25},
                "cluster": {"replicas": 4},
                "seed": 7
            }"#,
        )
        .unwrap();
        assert_eq!(c.scheduler.policy, Policy::SarathiEdf);
        assert_eq!(c.scheduler.chunk_size, 128);
        assert!(!c.scheduler.dynamic_chunking);
        assert_eq!(c.scheduler.alpha, 0.25);
        assert_eq!(c.cluster.replicas, 4);
        assert_eq!(c.seed, 7);
    }

    #[test]
    fn json_custom_tiers() {
        let c = Config::from_json_str(
            r#"{"tiers": [
                {"name": "chat", "ttft_s": 2.0, "tbt_s": 0.03},
                {"name": "batch", "ttlt_s": 900}
            ]}"#,
        )
        .unwrap();
        assert_eq!(c.tiers.len(), 2);
        assert_eq!(c.tiers[0].slo, Slo::Interactive { ttft_s: 2.0, tbt_s: 0.03 });
        assert_eq!(c.tiers[1].slo, Slo::NonInteractive { ttlt_s: 900.0 });
    }

    #[test]
    fn rejects_bad_policy() {
        assert!(Config::from_json_str(r#"{"scheduler": {"policy": "lifo"}}"#).is_err());
    }

    #[test]
    fn rejects_invalid_chunk_relation() {
        let r = Config::from_json_str(
            r#"{"scheduler": {"chunk_size": 512, "max_chunk_size": 128}}"#,
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_zero_replicas() {
        assert!(Config::from_json_str(r#"{"cluster": {"replicas": 0}}"#).is_err());
    }

    #[test]
    fn dispatch_defaults_to_seed_behavior() {
        let c = Config::default();
        assert_eq!(c.cluster.dispatch.policy, DispatchPolicy::RoundRobin);
        assert!(!c.cluster.dispatch.relegation_handoff);
    }

    #[test]
    fn json_dispatch_overrides() {
        let c = Config::from_json_str(
            r#"{"cluster": {"replicas": 8, "dispatch": "least-loaded",
                            "relegation_handoff": true}}"#,
        )
        .unwrap();
        assert_eq!(c.cluster.replicas, 8);
        assert_eq!(c.cluster.dispatch.policy, DispatchPolicy::LeastLoaded);
        assert!(c.cluster.dispatch.relegation_handoff);
    }

    #[test]
    fn rejects_unknown_dispatch_policy() {
        assert!(Config::from_json_str(r#"{"cluster": {"dispatch": "random"}}"#).is_err());
    }

    #[test]
    fn dispatch_policy_names_round_trip() {
        for p in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::JoinShortestQueue,
            DispatchPolicy::LeastLoaded,
            DispatchPolicy::PowerOfTwoChoices,
            DispatchPolicy::PredictedTtft,
            DispatchPolicy::TierAffinity,
        ] {
            assert_eq!(DispatchPolicy::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn json_pools_build_heterogeneous_spec() {
        let c = Config::from_json_str(
            r#"{"cluster": {"dispatch": "least-loaded", "pools": [
                {"name": "strict", "replicas": 2, "chunk_size": 256,
                 "policy": "niyama", "max_chunk_size": 2048},
                {"name": "batch", "replicas": 2, "chunk_size": 2048,
                 "policy": "sarathi-fcfs", "tier_affinity": [1, 2],
                 "min_replicas": 1, "max_replicas": 4}
            ]}}"#,
        )
        .unwrap();
        let spec = c.cluster_spec();
        assert_eq!(spec.pools.len(), 2);
        assert_eq!(spec.total_replicas(), 4);
        let strict = &spec.pools[0];
        assert_eq!(strict.spec.scheduler.policy, Policy::Niyama);
        assert_eq!(strict.spec.scheduler.chunk_size, 256);
        assert!(strict.spec.tier_affinity.is_empty());
        assert_eq!(strict.spec.affinity_mask(), 0);
        // Static by default: bounds pin to the initial size.
        assert_eq!((strict.min_replicas, strict.max_replicas), (2, 2));
        let batch = &spec.pools[1];
        assert_eq!(batch.spec.scheduler.policy, Policy::SarathiFcfs);
        assert_eq!(batch.spec.scheduler.chunk_size, 2048);
        assert_eq!(batch.spec.scheduler.max_chunk_size, 2048, "sarathi pools fix the chunk");
        assert_eq!(batch.spec.tier_affinity, vec![1, 2]);
        assert_eq!(batch.spec.affinity_mask(), 0b110);
        assert_eq!((batch.min_replicas, batch.max_replicas), (1, 4));
    }

    #[test]
    fn homogeneous_spec_is_the_one_pool_shim() {
        let cfg = Config::default();
        let spec = cfg.cluster_spec();
        assert_eq!(spec.pools.len(), 1);
        assert_eq!(spec.total_replicas(), cfg.cluster.replicas);
        assert_eq!(spec.reference_spec().scheduler.chunk_size, cfg.scheduler.chunk_size);
        assert_eq!(spec.reference_spec().hardware.name, cfg.hardware.name);
        spec.validate(cfg.tiers.len()).unwrap();
    }

    #[test]
    fn pool_validation_catches_drift_and_bad_bounds() {
        // Affinity naming a tier that does not exist — the indexing
        // drift the old silo sizing could hit silently.
        assert!(Config::from_json_str(
            r#"{"cluster": {"pools": [
                {"name": "p", "replicas": 1, "tier_affinity": [7]}]}}"#
        )
        .is_err());
        assert!(Config::from_json_str(
            r#"{"cluster": {"pools": [
                {"name": "p", "replicas": 1, "min_replicas": 3, "max_replicas": 2}]}}"#
        )
        .is_err());
        // Duplicate names.
        assert!(Config::from_json_str(
            r#"{"cluster": {"pools": [
                {"name": "p", "replicas": 1}, {"name": "p", "replicas": 1}]}}"#
        )
        .is_err());
        // No initial capacity anywhere.
        assert!(Config::from_json_str(
            r#"{"cluster": {"pools": [{"name": "p", "replicas": 0}]}}"#
        )
        .is_err());
        // Control-level bounds conflict with per-pool bounds: with pools
        // configured they would be silently ignored, so they are
        // rejected outright.
        assert!(Config::from_json_str(
            r#"{"cluster": {"pools": [{"name": "p", "replicas": 1}],
                "control": {"max_replicas": 8}}}"#
        )
        .is_err());
        // Pools with a plain autoscale policy (bounds on the pools) are
        // fine, and the pool hardware preset table matches the global
        // one ("tiny-cpu" works in both).
        let c = Config::from_json_str(
            r#"{"hardware": {"preset": "tiny-cpu"},
                "cluster": {"pools": [
                    {"name": "p", "replicas": 1, "hardware": "tiny-cpu",
                     "min_replicas": 1, "max_replicas": 2}],
                "control": {"autoscale": "reactive"}}}"#,
        )
        .unwrap();
        assert_eq!(c.cluster.pools[0].spec.hardware.name, "tiny-cpu");
    }

    #[test]
    fn control_defaults_are_off_and_valid() {
        let c = Config::default();
        assert_eq!(c.cluster.control.autoscale, AutoscalePolicy::Off);
        assert_eq!(
            c.cluster.control.admission,
            crate::simulator::dispatch::AdmissionPolicy::None
        );
        c.validate().unwrap();
    }

    #[test]
    fn json_control_overrides() {
        let c = Config::from_json_str(
            r#"{"cluster": {"replicas": 2, "control": {
                "autoscale": "predictive", "min_replicas": 2, "max_replicas": 6,
                "warmup_s": 15, "control_interval_s": 2.5,
                "scale_up_queue_s": 3, "scale_down_queue_s": 0.25,
                "hold_s": 5, "admission": "degrade"}}}"#,
        )
        .unwrap();
        let k = &c.cluster.control;
        assert_eq!(k.autoscale, AutoscalePolicy::Predictive);
        assert_eq!(k.min_replicas, 2);
        assert_eq!(k.max_replicas, 6);
        assert_eq!(k.warmup_s, 15.0);
        assert_eq!(k.control_interval_s, 2.5);
        assert_eq!(k.admission, crate::simulator::dispatch::AdmissionPolicy::Degrade);
    }

    #[test]
    fn rejects_bad_control_bounds() {
        assert!(Config::from_json_str(
            r#"{"cluster": {"control": {"min_replicas": 4, "max_replicas": 2}}}"#
        )
        .is_err());
        assert!(Config::from_json_str(r#"{"cluster": {"control": {"min_replicas": 0}}}"#)
            .is_err());
        assert!(Config::from_json_str(
            r#"{"cluster": {"control": {"autoscale": "magic"}}}"#
        )
        .is_err());
    }

    #[test]
    fn autoscale_policy_names_round_trip() {
        for p in [AutoscalePolicy::Off, AutoscalePolicy::Reactive, AutoscalePolicy::Predictive] {
            assert_eq!(AutoscalePolicy::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn interconnect_defaults_off_and_parses() {
        assert!(Config::default().cluster.interconnect.is_none());
        // An empty object takes the defaults (25 GB/s, 1 ms).
        let c = Config::from_json_str(r#"{"cluster": {"interconnect": {}}}"#).unwrap();
        assert_eq!(c.cluster.interconnect, Some(InterconnectConfig::default()));
        let c = Config::from_json_str(
            r#"{"cluster": {"interconnect": {"bandwidth_gbytes_per_s": 100, "latency_s": 0.005}}}"#,
        )
        .unwrap();
        let ic = c.cluster.interconnect.unwrap();
        assert_eq!(ic.bandwidth_gbytes_per_s, 100.0);
        assert_eq!(ic.latency_s, 0.005);
        // Zero bandwidth is legal (it disables migration), negative is not.
        assert!(Config::from_json_str(
            r#"{"cluster": {"interconnect": {"bandwidth_gbytes_per_s": 0}}}"#
        )
        .is_ok());
        assert!(Config::from_json_str(
            r#"{"cluster": {"interconnect": {"bandwidth_gbytes_per_s": -1}}}"#
        )
        .is_err());
        assert!(Config::from_json_str(
            r#"{"cluster": {"interconnect": {"latency_s": -0.5}}}"#
        )
        .is_err());
    }

    #[test]
    fn parallel_defaults_off_and_parses() {
        assert!(Config::default().cluster.parallel.is_none());
        // An empty object means "sharded with 1 worker" = sequential.
        let c = Config::from_json_str(r#"{"cluster": {"parallel": {}}}"#).unwrap();
        assert_eq!(c.cluster.parallel, Some(ParallelConfig { workers: 1 }));
        assert_eq!(c.cluster.effective_workers(), 1);
        let c = Config::from_json_str(r#"{"cluster": {"parallel": {"workers": 8}}}"#).unwrap();
        assert_eq!(c.cluster.parallel, Some(ParallelConfig { workers: 8 }));
        assert_eq!(c.cluster.effective_workers(), 8);
        // workers: 0 is a config error, not a silent fallback.
        assert!(Config::from_json_str(r#"{"cluster": {"parallel": {"workers": 0}}}"#).is_err());
    }

    #[test]
    fn explicit_parallel_config_beats_env_default() {
        // Explicit block wins regardless of NIYAMA_WORKERS (the env var
        // only supplies the default when the block is absent) — asserted
        // without touching the process env, which other tests share.
        let c = Config::from_json_str(r#"{"cluster": {"parallel": {"workers": 3}}}"#).unwrap();
        assert_eq!(c.cluster.effective_workers(), 3);
        // Absent block: 1 or whatever NIYAMA_WORKERS says — both legal.
        assert!(Config::default().cluster.effective_workers() >= 1);
    }

    #[test]
    fn audit_defaults_off_and_parses() {
        assert!(Config::default().cluster.audit.is_none());
        // An empty block means "audit on" — presence is the opt-in.
        let c = Config::from_json_str(r#"{"cluster": {"audit": {}}}"#).unwrap();
        assert_eq!(c.cluster.audit, Some(AuditConfig { enabled: true }));
        assert!(c.cluster.effective_audit());
        // An explicit `enabled: false` pins the auditor off even under
        // NIYAMA_AUDIT=1 (the block beats the env var).
        let c = Config::from_json_str(r#"{"cluster": {"audit": {"enabled": false}}}"#).unwrap();
        assert_eq!(c.cluster.audit, Some(AuditConfig { enabled: false }));
        assert!(!c.cluster.effective_audit());
    }

    #[test]
    fn profiling_defaults_off_and_parses() {
        assert!(Config::default().cluster.profiling.is_none());
        // An empty block means "profile" — presence is the opt-in.
        let c = Config::from_json_str(r#"{"cluster": {"profiling": {}}}"#).unwrap();
        assert_eq!(c.cluster.profiling, Some(ProfilingConfig { enabled: true }));
        assert!(c.cluster.effective_profiling());
        // An explicit `enabled: false` pins the profiler off even under
        // NIYAMA_PROF=1 (the block beats the env var).
        let c = Config::from_json_str(r#"{"cluster": {"profiling": {"enabled": false}}}"#).unwrap();
        assert_eq!(c.cluster.profiling, Some(ProfilingConfig { enabled: false }));
        assert!(!c.cluster.effective_profiling());
    }

    #[test]
    fn pool_interconnect_overrides_parse_and_validate() {
        let c = Config::from_json_str(
            r#"{"cluster": {
                "interconnect": {"bandwidth_gbytes_per_s": 25},
                "pools": [
                    {"name": "fast", "replicas": 1,
                     "interconnect": {"bandwidth_gbytes_per_s": 100, "latency_s": 0.0005}},
                    {"name": "inherits", "replicas": 1}
                ]}}"#,
        )
        .unwrap();
        let fast = c.cluster.pools[0].interconnect.unwrap();
        assert_eq!((fast.bandwidth_gbytes_per_s, fast.latency_s), (100.0, 0.0005));
        assert!(c.cluster.pools[1].interconnect.is_none(), "absent = inherit cluster-level");
        // Per-pool values are validated like the cluster-level ones.
        assert!(Config::from_json_str(
            r#"{"cluster": {"pools": [
                {"name": "p", "replicas": 1,
                 "interconnect": {"bandwidth_gbytes_per_s": -5}}]}}"#
        )
        .is_err());
    }

    #[test]
    fn json_dispatch_seed_override() {
        let c = Config::from_json_str(
            r#"{"cluster": {"replicas": 4, "dispatch": "p2c", "dispatch_seed": 99}}"#,
        )
        .unwrap();
        assert_eq!(c.cluster.dispatch.policy, DispatchPolicy::PowerOfTwoChoices);
        assert_eq!(c.cluster.dispatch.seed, 99);
    }

    #[test]
    fn policy_names_round_trip() {
        for p in [
            Policy::Niyama,
            Policy::SarathiFcfs,
            Policy::SarathiEdf,
            Policy::SarathiSrpf,
            Policy::SarathiSjf,
        ] {
            assert_eq!(Policy::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn shipped_config_files_load() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
        for name in [
            "shared_niyama.json",
            "sarathi_edf_baseline.json",
            "qwen_tp2.json",
            "hetero_pools.json",
            "live_migration.json",
            "sessions.json",
        ] {
            let path = dir.join(name);
            let cfg = Config::from_file(path.to_str().unwrap())
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            cfg.validate().unwrap();
        }
        // And spot-check a value from each.
        let edf = Config::from_file(dir.join("sarathi_edf_baseline.json").to_str().unwrap()).unwrap();
        assert_eq!(edf.scheduler.policy, Policy::SarathiEdf);
        let qwen = Config::from_file(dir.join("qwen_tp2.json").to_str().unwrap()).unwrap();
        assert_eq!(qwen.hardware.tp_degree, 2);
        let hetero = Config::from_file(dir.join("hetero_pools.json").to_str().unwrap()).unwrap();
        let spec = hetero.cluster_spec();
        assert_eq!(spec.pools.len(), 2);
        assert_eq!(spec.pools[1].spec.affinity_mask(), 0b110);
        assert_eq!(hetero.cluster.dispatch.policy, DispatchPolicy::LeastLoaded);
        let mig = Config::from_file(dir.join("live_migration.json").to_str().unwrap()).unwrap();
        let ic = mig.cluster.interconnect.expect("interconnect configured");
        assert!(ic.bandwidth_gbytes_per_s > 0.0);
        let sess = Config::from_file(dir.join("sessions.json").to_str().unwrap()).unwrap();
        assert_eq!(sess.cluster.dispatch.policy, DispatchPolicy::CacheAffinity);
        let pc = sess.cluster.prefix_cache.expect("prefix cache configured");
        assert_eq!((pc.capacity_frac, pc.block_tokens), (0.2, 64));
        let sc = sess.session.expect("session workload configured");
        assert_eq!(sc.mean_turns, 5.0);
        assert_eq!(sc.flash_frac, 0.3);
    }

    #[test]
    fn sarathi_preset_disables_niyama_features() {
        let s = SchedulerConfig::sarathi(Policy::SarathiFcfs, 256);
        assert!(!s.dynamic_chunking && !s.eager_relegation && !s.hybrid_priority);
        assert_eq!(s.max_chunk_size, 256);
    }
}
