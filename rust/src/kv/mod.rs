//! KV-cache management.
//!
//! Two layers:
//! - [`BlockLedger`]: paged block accounting (vLLM-style) — allocation,
//!   growth and release in fixed-size token blocks, used for admission
//!   control and memory-pressure accounting on both backends.
//! - [`KvStore`]: host-side cache storage for the real PJRT path — one
//!   `(L,2,Hkv,S,D)` f32 buffer per in-flight request, recycled through a
//!   free pool to keep the serving loop allocation-free in steady state.

use crate::request::RequestId;
use std::collections::HashMap;

/// Paged block accounting (no data, just occupancy).
#[derive(Debug)]
pub struct BlockLedger {
    block_tokens: u32,
    total_blocks: u64,
    free_blocks: u64,
    held: HashMap<RequestId, u64>,
}

impl BlockLedger {
    pub fn new(capacity_tokens: u64, block_tokens: u32) -> Self {
        assert!(block_tokens > 0);
        let total_blocks = capacity_tokens / block_tokens as u64;
        BlockLedger { block_tokens, total_blocks, free_blocks: total_blocks, held: HashMap::new() }
    }

    fn blocks_for(&self, tokens: u32) -> u64 {
        ((tokens + self.block_tokens - 1) / self.block_tokens) as u64
    }

    /// Ensure `id` holds enough blocks for `tokens`; allocates the delta.
    /// Returns false (and changes nothing) if capacity is insufficient.
    pub fn reserve(&mut self, id: RequestId, tokens: u32) -> bool {
        let need = self.blocks_for(tokens);
        let have = *self.held.get(&id).unwrap_or(&0);
        if need <= have {
            return true;
        }
        let delta = need - have;
        if delta > self.free_blocks {
            return false;
        }
        self.free_blocks -= delta;
        self.held.insert(id, need);
        true
    }

    /// Release all blocks held by `id`.
    pub fn release(&mut self, id: RequestId) {
        if let Some(blocks) = self.held.remove(&id) {
            self.free_blocks += blocks;
        }
    }

    pub fn free_tokens(&self) -> u64 {
        self.free_blocks * self.block_tokens as u64
    }

    pub fn used_tokens(&self) -> u64 {
        (self.total_blocks - self.free_blocks) * self.block_tokens as u64
    }

    pub fn holders(&self) -> usize {
        self.held.len()
    }
}

/// Host-side KV buffers for the PJRT path.
#[derive(Debug, Default)]
pub struct KvStore {
    elements_per_seq: usize,
    caches: HashMap<RequestId, Vec<f32>>,
    /// Recycled buffers (avoid realloc+zeroing cost per request).
    pool: Vec<Vec<f32>>,
}

impl KvStore {
    pub fn new(elements_per_seq: usize) -> Self {
        KvStore { elements_per_seq, caches: HashMap::new(), pool: Vec::new() }
    }

    /// Get (allocating if needed) the cache buffer for a request.
    pub fn entry(&mut self, id: RequestId) -> &mut Vec<f32> {
        if !self.caches.contains_key(&id) {
            let mut buf = self.pool.pop().unwrap_or_default();
            buf.clear();
            buf.resize(self.elements_per_seq, 0.0);
            self.caches.insert(id, buf);
        }
        self.caches.get_mut(&id).unwrap()
    }

    pub fn contains(&self, id: RequestId) -> bool {
        self.caches.contains_key(&id)
    }

    /// Release a request's buffer back to the pool.
    pub fn release(&mut self, id: RequestId) {
        if let Some(buf) = self.caches.remove(&id) {
            self.pool.push(buf);
        }
    }

    /// Mutable access to several caches at once (decode batch assembly).
    /// Panics if an id is missing or duplicated.
    pub fn get_many_mut(&mut self, ids: &[RequestId]) -> Vec<&mut [f32]> {
        // Safety dance via raw pointers: ids are checked for uniqueness.
        for (i, a) in ids.iter().enumerate() {
            for b in &ids[i + 1..] {
                assert_ne!(a, b, "duplicate request id in decode batch");
            }
        }
        let mut out = Vec::with_capacity(ids.len());
        for &id in ids {
            let buf = self.caches.get_mut(&id).expect("kv cache missing") as *mut Vec<f32>;
            // SAFETY: uniqueness checked above; lifetimes tied to &mut self.
            out.push(unsafe { (*buf).as_mut_slice() });
        }
        out
    }

    pub fn live(&self) -> usize {
        self.caches.len()
    }

    pub fn pooled(&self) -> usize {
        self.pool.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_reserve_and_release() {
        let mut l = BlockLedger::new(1000, 16); // 62 blocks
        assert!(l.reserve(1, 100)); // 7 blocks
        assert_eq!(l.used_tokens(), 7 * 16);
        assert!(l.reserve(1, 100), "idempotent");
        assert_eq!(l.used_tokens(), 7 * 16);
        assert!(l.reserve(1, 200)); // grow to 13 blocks
        assert_eq!(l.used_tokens(), 13 * 16);
        l.release(1);
        assert_eq!(l.used_tokens(), 0);
        assert_eq!(l.holders(), 0);
    }

    #[test]
    fn ledger_denies_over_capacity() {
        let mut l = BlockLedger::new(100, 10); // 10 blocks
        assert!(l.reserve(1, 60));
        assert!(!l.reserve(2, 50), "only 4 blocks left");
        assert!(l.reserve(2, 40));
        assert_eq!(l.free_tokens(), 0);
    }

    #[test]
    fn ledger_rounds_to_blocks() {
        let mut l = BlockLedger::new(100, 16);
        assert!(l.reserve(1, 1)); // one whole block
        assert_eq!(l.used_tokens(), 16);
    }

    #[test]
    fn kvstore_allocates_and_recycles() {
        let mut s = KvStore::new(64);
        s.entry(1)[0] = 5.0;
        s.entry(2);
        assert_eq!(s.live(), 2);
        s.release(1);
        assert_eq!(s.live(), 1);
        assert_eq!(s.pooled(), 1);
        // Recycled buffer is zeroed.
        assert_eq!(s.entry(3)[0], 0.0);
        assert_eq!(s.pooled(), 0);
    }

    #[test]
    fn kvstore_get_many_mut() {
        let mut s = KvStore::new(4);
        s.entry(1)[0] = 1.0;
        s.entry(2)[0] = 2.0;
        let bufs = s.get_many_mut(&[1, 2]);
        assert_eq!(bufs[0][0], 1.0);
        assert_eq!(bufs[1][0], 2.0);
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn kvstore_rejects_duplicates() {
        let mut s = KvStore::new(4);
        s.entry(1);
        let _ = s.get_many_mut(&[1, 1]);
    }
}
