//! KV-cache management.
//!
//! Two layers:
//! - [`BlockLedger`]: paged block accounting (vLLM-style) — allocation,
//!   growth and release in fixed-size token blocks, used for admission
//!   control and memory-pressure accounting on both backends.
//! - [`KvStore`]: host-side cache storage for the real PJRT path — one
//!   `(L,2,Hkv,S,D)` f32 buffer per in-flight request, recycled through a
//!   free pool to keep the serving loop allocation-free in steady state.
//!
//! This is one of exactly two modules in the crate permitted to contain
//! `unsafe` (the other is [`crate::simulator::stripes`]), kept on the
//! allowlist for host-side buffer work: [`KvStore::get_many_mut`]'s
//! batched disjoint borrows were the crate's original raw-pointer site
//! until the audit rewrote them in safe code (see the provenance note
//! there); the Miri CI leg keeps this module's tests aliasing-clean
//! either way. `tools/conformance_lint` enforces the allowlist.

#![allow(unsafe_code)]
#![deny(unsafe_op_in_unsafe_fn)]
#![deny(clippy::undocumented_unsafe_blocks)]

use crate::request::RequestId;
use std::collections::HashMap;

/// Paged block accounting (no data, just occupancy).
#[derive(Debug)]
pub struct BlockLedger {
    block_tokens: u32,
    total_blocks: u64,
    free_blocks: u64,
    held: HashMap<RequestId, u64>,
}

impl BlockLedger {
    pub fn new(capacity_tokens: u64, block_tokens: u32) -> Self {
        assert!(block_tokens > 0);
        let total_blocks = capacity_tokens / block_tokens as u64;
        BlockLedger { block_tokens, total_blocks, free_blocks: total_blocks, held: HashMap::new() }
    }

    fn blocks_for(&self, tokens: u32) -> u64 {
        // Round up in u64: `tokens + block_tokens - 1` wraps in u32 for
        // prompts near u32::MAX.
        (tokens as u64 + self.block_tokens as u64 - 1) / self.block_tokens as u64
    }

    /// Ensure `id` holds enough blocks for `tokens`; allocates the delta.
    /// Returns false (and changes nothing) if capacity is insufficient.
    pub fn reserve(&mut self, id: RequestId, tokens: u32) -> bool {
        let need = self.blocks_for(tokens);
        let have = *self.held.get(&id).unwrap_or(&0);
        if need <= have {
            return true;
        }
        let delta = need - have;
        if delta > self.free_blocks {
            return false;
        }
        self.free_blocks -= delta;
        self.held.insert(id, need);
        true
    }

    /// Release all blocks held by `id`.
    pub fn release(&mut self, id: RequestId) {
        if let Some(blocks) = self.held.remove(&id) {
            self.free_blocks += blocks;
        }
    }

    pub fn free_tokens(&self) -> u64 {
        self.free_blocks * self.block_tokens as u64
    }

    pub fn used_tokens(&self) -> u64 {
        (self.total_blocks - self.free_blocks) * self.block_tokens as u64
    }

    pub fn holders(&self) -> usize {
        self.held.len()
    }
}

/// Stable key of a multi-turn session (`RequestSpec::session_id`).
pub type SessionId = u64;

/// Per-replica cache of retained session-prefix KV.
///
/// After a turn finishes, its full KV (prompt + generated tokens) may be
/// retained so the session's next turn skips re-prefilling the shared
/// prefix. Residency is charged block-granular through an embedded
/// [`BlockLedger`] — the same accounting currency as live requests — and
/// the engine shrinks the cache on demand (`evict_to`) whenever live
/// work needs the headroom, so retained prefixes always lose to live
/// requests. Eviction is LRU over whole sessions, ordered by a
/// monotonic touch tick (deterministic: no wall clock, no hash-map
/// iteration order).
#[derive(Debug)]
pub struct PrefixCache {
    ledger: BlockLedger,
    block_tokens: u32,
    budget_tokens: u64,
    /// session → (retained prefix tokens, ledger handle, last-touch tick)
    entries: HashMap<SessionId, (u32, RequestId, u64)>,
    next_handle: RequestId,
    tick: u64,
    /// Admission-time lookups (one per session-tagged arrival).
    pub lookups: u64,
    /// Lookups that matched a non-empty block-aligned prefix.
    pub hits: u64,
    /// Prefill tokens skipped across all hits.
    pub tokens_saved: u64,
}

impl PrefixCache {
    pub fn new(budget_tokens: u64, block_tokens: u32) -> Self {
        assert!(block_tokens > 0);
        PrefixCache {
            ledger: BlockLedger::new(budget_tokens, block_tokens),
            block_tokens,
            budget_tokens,
            entries: HashMap::new(),
            next_handle: 0,
            tick: 0,
            lookups: 0,
            hits: 0,
            tokens_saved: 0,
        }
    }

    pub fn budget_tokens(&self) -> u64 {
        self.budget_tokens
    }

    /// KV tokens the cache currently occupies (block-rounded).
    pub fn resident_tokens(&self) -> u64 {
        self.ledger.used_tokens()
    }

    /// Retained sessions, sorted by session id — the per-replica cache
    /// summary published in `LoadSnapshot`.
    pub fn sessions(&self) -> Vec<(SessionId, u32)> {
        let mut v: Vec<(SessionId, u32)> =
            self.entries.iter().map(|(&s, &(tok, _, _))| (s, tok)).collect();
        v.sort_unstable_by_key(|&(s, _)| s);
        v
    }

    /// Non-mutating peek at a session's retained prefix length (tokens,
    /// not block-floored). Returns 0 for unknown sessions.
    pub fn cached_prefix(&self, session: SessionId) -> u32 {
        self.entries.get(&session).map_or(0, |&(tok, _, _)| tok)
    }

    /// Usable hit length: the block-aligned part of the retained prefix,
    /// capped at `wanted` (the arriving turn's shared-prefix tokens).
    fn usable(&self, cached: u32, wanted: u32) -> u32 {
        let m = cached.min(wanted);
        m - m % self.block_tokens
    }

    /// Longest-prefix match for an arriving turn: returns how many of its
    /// first `wanted` prompt tokens are already resident (block-aligned),
    /// touches the entry for LRU, and bumps the hit counters.
    pub fn lookup(&mut self, session: SessionId, wanted: u32) -> u32 {
        self.lookups += 1;
        self.tick += 1;
        let tick = self.tick;
        let hit = match self.entries.get_mut(&session) {
            Some(e) => {
                e.2 = tick;
                let cached = e.0;
                self.usable(cached, wanted)
            }
            None => 0,
        };
        if hit > 0 {
            self.hits += 1;
            self.tokens_saved += hit as u64;
        }
        hit
    }

    /// Retain a finished turn's KV: the session's prefix grows to
    /// `tokens` (never shrinks on insert). Evicts least-recently-used
    /// *other* sessions until the block-rounded residency fits the
    /// budget; a prefix larger than the whole budget is truncated to it.
    pub fn insert(&mut self, session: SessionId, tokens: u32) {
        self.tick += 1;
        let tick = self.tick;
        let cap = (self.budget_tokens / self.block_tokens as u64) * self.block_tokens as u64;
        let tokens = (tokens as u64).min(cap).min(u32::MAX as u64) as u32;
        if tokens == 0 {
            return;
        }
        let handle = match self.entries.get_mut(&session) {
            Some(e) => {
                e.2 = tick;
                if tokens <= e.0 {
                    return;
                }
                e.0 = tokens;
                e.1
            }
            None => {
                let h = self.next_handle;
                self.next_handle = self.next_handle.wrapping_add(1);
                self.entries.insert(session, (tokens, h, tick));
                h
            }
        };
        while !self.ledger.reserve(handle, tokens) {
            if !self.evict_lru(Some(session)) {
                // Nothing else to evict and it still does not fit: drop
                // the entry rather than retain a lie.
                if let Some((_, h, _)) = self.entries.remove(&session) {
                    self.ledger.release(h);
                }
                return;
            }
        }
    }

    /// Shrink residency to at most `limit` tokens, evicting whole LRU
    /// sessions. The engine calls this with the KV headroom left after
    /// live requests, so cache residency always yields to live work.
    pub fn evict_to(&mut self, limit: u64) {
        while self.resident_tokens() > limit {
            if !self.evict_lru(None) {
                break;
            }
        }
    }

    /// Evict the least-recently-touched session (skipping `keep`).
    /// Returns false when there was nothing to evict.
    fn evict_lru(&mut self, keep: Option<SessionId>) -> bool {
        let victim = self
            .entries
            .iter()
            .filter(|(&s, _)| Some(s) != keep)
            .min_by_key(|(_, &(_, _, tick))| tick)
            .map(|(&s, _)| s);
        match victim {
            Some(s) => {
                if let Some((_, h, _)) = self.entries.remove(&s) {
                    self.ledger.release(h);
                }
                true
            }
            None => false,
        }
    }
}

/// Host-side KV buffers for the PJRT path.
#[derive(Debug, Default)]
pub struct KvStore {
    elements_per_seq: usize,
    caches: HashMap<RequestId, Vec<f32>>,
    /// Recycled buffers (avoid realloc+zeroing cost per request).
    pool: Vec<Vec<f32>>,
}

impl KvStore {
    pub fn new(elements_per_seq: usize) -> Self {
        KvStore { elements_per_seq, caches: HashMap::new(), pool: Vec::new() }
    }

    /// Get (allocating if needed) the cache buffer for a request.
    pub fn entry(&mut self, id: RequestId) -> &mut Vec<f32> {
        if !self.caches.contains_key(&id) {
            let mut buf = self.pool.pop().unwrap_or_default();
            buf.clear();
            buf.resize(self.elements_per_seq, 0.0);
            self.caches.insert(id, buf);
        }
        self.caches.get_mut(&id).unwrap()
    }

    pub fn contains(&self, id: RequestId) -> bool {
        self.caches.contains_key(&id)
    }

    /// Release a request's buffer back to the pool.
    pub fn release(&mut self, id: RequestId) {
        if let Some(buf) = self.caches.remove(&id) {
            self.pool.push(buf);
        }
    }

    /// Mutable access to several caches at once (decode batch assembly).
    /// Panics if an id is missing or duplicated.
    ///
    /// Provenance note: per-id `get_mut` calls cannot hand out
    /// simultaneously live `&mut`s — every call re-borrows the whole
    /// map and, under the aliasing model Miri enforces, invalidates the
    /// borrows already returned (the pre-audit version did exactly that
    /// through raw pointers). One `iter_mut` traversal instead yields
    /// disjoint borrows that are all live at once, in entirely safe
    /// code; the batch is then emitted in `ids` order.
    pub fn get_many_mut(&mut self, ids: &[RequestId]) -> Vec<&mut [f32]> {
        // Small batches keep the branch-free pairwise duplicate scan;
        // past the threshold a sort of a scratch copy is O(n log n)
        // instead of the ~32k comparisons a 256-wide decode batch used
        // to pay — and doubles as the membership index below.
        const PAIRWISE_MAX: usize = 16;
        let mut sorted: Option<Vec<RequestId>> = None;
        if ids.len() <= PAIRWISE_MAX {
            for (i, a) in ids.iter().enumerate() {
                for b in &ids[i + 1..] {
                    assert_ne!(a, b, "duplicate request id in decode batch");
                }
            }
        } else {
            let mut s = ids.to_vec();
            s.sort_unstable();
            for w in s.windows(2) {
                assert_ne!(w[0], w[1], "duplicate request id in decode batch");
            }
            sorted = Some(s);
        }
        let wanted = |id: &RequestId| match &sorted {
            Some(s) => s.binary_search(id).is_ok(),
            None => ids.contains(id),
        };
        let mut grabbed: HashMap<RequestId, &mut [f32]> = HashMap::with_capacity(ids.len());
        for (id, buf) in self.caches.iter_mut() {
            if wanted(id) {
                grabbed.insert(*id, buf.as_mut_slice());
            }
        }
        ids.iter().map(|id| grabbed.remove(id).expect("kv cache missing")).collect()
    }

    pub fn live(&self) -> usize {
        self.caches.len()
    }

    pub fn pooled(&self) -> usize {
        self.pool.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_reserve_and_release() {
        let mut l = BlockLedger::new(1000, 16); // 62 blocks
        assert!(l.reserve(1, 100)); // 7 blocks
        assert_eq!(l.used_tokens(), 7 * 16);
        assert!(l.reserve(1, 100), "idempotent");
        assert_eq!(l.used_tokens(), 7 * 16);
        assert!(l.reserve(1, 200)); // grow to 13 blocks
        assert_eq!(l.used_tokens(), 13 * 16);
        l.release(1);
        assert_eq!(l.used_tokens(), 0);
        assert_eq!(l.holders(), 0);
    }

    #[test]
    fn ledger_denies_over_capacity() {
        let mut l = BlockLedger::new(100, 10); // 10 blocks
        assert!(l.reserve(1, 60));
        assert!(!l.reserve(2, 50), "only 4 blocks left");
        assert!(l.reserve(2, 40));
        assert_eq!(l.free_tokens(), 0);
    }

    #[test]
    fn ledger_rounds_to_blocks() {
        let mut l = BlockLedger::new(100, 16);
        assert!(l.reserve(1, 1)); // one whole block
        assert_eq!(l.used_tokens(), 16);
    }

    #[test]
    fn kvstore_allocates_and_recycles() {
        let mut s = KvStore::new(64);
        s.entry(1)[0] = 5.0;
        s.entry(2);
        assert_eq!(s.live(), 2);
        s.release(1);
        assert_eq!(s.live(), 1);
        assert_eq!(s.pooled(), 1);
        // Recycled buffer is zeroed.
        assert_eq!(s.entry(3)[0], 0.0);
        assert_eq!(s.pooled(), 0);
    }

    #[test]
    fn kvstore_get_many_mut() {
        let mut s = KvStore::new(4);
        s.entry(1)[0] = 1.0;
        s.entry(2)[0] = 2.0;
        let bufs = s.get_many_mut(&[1, 2]);
        assert_eq!(bufs[0][0], 1.0);
        assert_eq!(bufs[1][0], 2.0);
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn kvstore_rejects_duplicates() {
        let mut s = KvStore::new(4);
        s.entry(1);
        let _ = s.get_many_mut(&[1, 1]);
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn kvstore_rejects_duplicates_in_large_batches() {
        // Past the pairwise threshold the sort-based check must still
        // catch a duplicate.
        let mut s = KvStore::new(4);
        let mut ids: Vec<RequestId> = (0..32).collect();
        for &id in &ids {
            s.entry(id);
        }
        ids.push(7);
        let _ = s.get_many_mut(&ids);
    }

    #[test]
    fn kvstore_get_many_mut_borrows_are_disjoint_and_live_together() {
        // The aliasing regression the audit rewrite guards against:
        // every returned slice must stay writable while all the others
        // are live (the old per-id raw-pointer dance invalidated earlier
        // borrows on each lookup — Miri flags that pattern), writes must
        // land in the right buffer, and order must follow `ids`, not map
        // iteration order.
        let mut s = KvStore::new(4);
        for id in 0..20u32 {
            s.entry(id)[0] = id as f32;
        }
        let ids: Vec<RequestId> = vec![13, 2, 7, 19, 0];
        let mut bufs = s.get_many_mut(&ids);
        for (k, buf) in bufs.iter_mut().enumerate() {
            buf[1] = 100.0 + k as f32; // all five borrows live at once
        }
        for (k, buf) in bufs.iter().enumerate() {
            assert_eq!(buf[0], ids[k] as f32, "batch order must follow ids");
            assert_eq!(buf[1], 100.0 + k as f32);
        }
        drop(bufs);
        // Untouched entries must be exactly as allocated.
        assert_eq!(s.entry(1)[1], 0.0);
        assert_eq!(s.entry(13)[1], 100.0);
    }

    #[test]
    #[should_panic(expected = "kv cache missing")]
    fn kvstore_get_many_mut_panics_on_missing_id() {
        let mut s = KvStore::new(4);
        s.entry(1);
        let _ = s.get_many_mut(&[1, 2]);
    }

    #[test]
    fn kvstore_get_many_mut_large_unique_batch() {
        let mut s = KvStore::new(4);
        let ids: Vec<RequestId> = (0..64).collect();
        for &id in &ids {
            s.entry(id)[0] = id as f32;
        }
        let bufs = s.get_many_mut(&ids);
        assert_eq!(bufs.len(), 64);
        assert_eq!(bufs[63][0], 63.0);
    }

    #[test]
    fn ledger_blocks_for_no_u32_overflow() {
        let mut l = BlockLedger::new(u32::MAX as u64 + 1024, 16);
        assert!(l.reserve(1, u32::MAX), "near-u32::MAX prompt must not wrap");
        assert!(l.used_tokens() >= u32::MAX as u64);
    }

    #[test]
    fn prefix_cache_block_aligned_hits() {
        let mut c = PrefixCache::new(10_000, 16);
        assert_eq!(c.lookup(1, 500), 0, "cold miss");
        c.insert(1, 100);
        // Retained 100 tokens; an arrival sharing 90 hits the aligned 80.
        assert_eq!(c.lookup(1, 90), 80);
        // Sharing more than retained: floor of the retained length.
        assert_eq!(c.lookup(1, 500), 96);
        assert_eq!(c.lookups, 3);
        assert_eq!(c.hits, 2);
        assert_eq!(c.tokens_saved, 80 + 96);
    }

    #[test]
    fn prefix_cache_insert_grows_never_shrinks() {
        let mut c = PrefixCache::new(10_000, 16);
        c.insert(1, 100);
        c.insert(1, 50);
        assert_eq!(c.cached_prefix(1), 100);
        c.insert(1, 160);
        assert_eq!(c.cached_prefix(1), 160);
        assert_eq!(c.resident_tokens(), 160);
    }

    #[test]
    fn prefix_cache_lru_eviction_on_budget() {
        // Budget = 4 blocks of 16 = 64 tokens.
        let mut c = PrefixCache::new(64, 16);
        c.insert(1, 32);
        c.insert(2, 32);
        c.lookup(1, 32); // touch 1: session 2 is now LRU
        c.insert(3, 32); // evicts 2
        assert_eq!(c.cached_prefix(2), 0);
        assert_eq!(c.cached_prefix(1), 32);
        assert_eq!(c.cached_prefix(3), 32);
        assert!(c.resident_tokens() <= 64);
    }

    #[test]
    fn prefix_cache_oversized_insert_truncates_to_budget() {
        let mut c = PrefixCache::new(64, 16);
        c.insert(1, 1000);
        assert_eq!(c.cached_prefix(1), 64);
        assert_eq!(c.resident_tokens(), 64);
    }

    #[test]
    fn prefix_cache_evict_to_yields_to_live_kv() {
        let mut c = PrefixCache::new(1000, 16);
        c.insert(1, 160);
        c.insert(2, 160);
        c.insert(3, 160);
        c.evict_to(200);
        assert!(c.resident_tokens() <= 200);
        // LRU order: 1 then 2 were evicted, 3 survives.
        assert_eq!(c.cached_prefix(3), 160);
        c.evict_to(0);
        assert_eq!(c.resident_tokens(), 0);
    }

    #[test]
    fn prefix_cache_sessions_summary_sorted() {
        let mut c = PrefixCache::new(1000, 16);
        c.insert(9, 32);
        c.insert(2, 16);
        c.insert(5, 48);
        assert_eq!(c.sessions(), vec![(2, 16), (5, 48), (9, 32)]);
    }
}
