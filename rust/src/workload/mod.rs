//! Workload synthesis: datasets, arrival processes, trace generation.
//!
//! The paper evaluates on ShareGPT and two Azure production traces
//! (Table 1). Those traces are not redistributable, so we fit lognormal
//! token-length distributions to the exact p50/p90 statistics the paper
//! publishes (DESIGN.md §2 records this substitution) and generate
//! arrivals from the processes the paper states: Poisson for uniform
//! load (§4), square-wave diurnal for the transient-overload study
//! (§4.3).

pub mod datasets;

use crate::qos::Importance;
use crate::request::RequestSpec;
use crate::util::Rng;
use datasets::Dataset;

/// Arrival process shapes used across the evaluation.
#[derive(Debug, Clone, Copy)]
pub enum ArrivalProcess {
    /// Poisson arrivals at a constant rate (paper §4.1-4.2).
    Poisson { qps: f64 },
    /// Square-wave diurnal pattern: alternates `low_qps` and `high_qps`
    /// every `period_s` seconds (paper §4.3: 2 ↔ 6 QPS every 15 min).
    Diurnal { low_qps: f64, high_qps: f64, period_s: f64 },
    /// A single burst: `base_qps` with a window of `burst_qps` between
    /// `burst_start_s` and `burst_end_s` (paper Fig. 1 bottom).
    Burst { base_qps: f64, burst_qps: f64, burst_start_s: f64, burst_end_s: f64 },
}

impl ArrivalProcess {
    fn rate_at(&self, t: f64) -> f64 {
        match *self {
            ArrivalProcess::Poisson { qps } => qps,
            ArrivalProcess::Diurnal { low_qps, high_qps, period_s } => {
                if ((t / period_s) as u64) % 2 == 0 {
                    low_qps
                } else {
                    high_qps
                }
            }
            ArrivalProcess::Burst { base_qps, burst_qps, burst_start_s, burst_end_s } => {
                if (burst_start_s..burst_end_s).contains(&t) {
                    burst_qps
                } else {
                    base_qps
                }
            }
        }
    }

    fn max_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { qps } => qps,
            ArrivalProcess::Diurnal { low_qps, high_qps, .. } => low_qps.max(high_qps),
            ArrivalProcess::Burst { base_qps, burst_qps, .. } => base_qps.max(burst_qps),
        }
    }

    /// Sample arrival times on [0, duration) via Lewis thinning (exact for
    /// piecewise-constant rates, and trivially correct for constant ones).
    pub fn sample(&self, duration_s: f64, rng: &mut Rng) -> Vec<f64> {
        let lambda_max = self.max_rate();
        assert!(lambda_max > 0.0, "arrival rate must be positive");
        let mut out = Vec::new();
        let mut t = 0.0;
        loop {
            t += rng.exponential(lambda_max);
            if t >= duration_s {
                break;
            }
            if rng.next_f64() < self.rate_at(t) / lambda_max {
                out.push(t);
            }
        }
        out
    }
}

/// Per-tier workload mixing policy.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub dataset: Dataset,
    pub arrivals: ArrivalProcess,
    pub duration_s: f64,
    /// Share of requests assigned to each configured QoS tier.
    /// The paper splits the dataset into three equal parts (Table 2).
    pub tier_shares: Vec<f64>,
    /// Fraction of each tier flagged low-importance (free tier) for
    /// relegation hints (paper §4.3 uses 20%).
    pub low_importance_frac: f64,
    /// Cap prompt/decode lengths (None = dataset native). The real-model
    /// PJRT path uses this to fit its max_seq.
    pub max_prompt: Option<u32>,
    pub max_decode: Option<u32>,
}

impl WorkloadSpec {
    pub fn uniform(dataset: Dataset, qps: f64, duration_s: f64) -> Self {
        WorkloadSpec {
            dataset,
            arrivals: ArrivalProcess::Poisson { qps },
            duration_s,
            tier_shares: vec![1.0 / 3.0, 1.0 / 3.0, 1.0 / 3.0],
            low_importance_frac: 0.0,
            max_prompt: None,
            max_decode: None,
        }
    }

    /// Generate the request trace. Tier assignment follows `tier_shares`
    /// i.i.d. per request; each tier maps to one synthetic "application"
    /// (`app_id == tier`), matching the paper's setup where each dataset
    /// third emulates a different application.
    pub fn generate(&self, rng: &mut Rng) -> Vec<RequestSpec> {
        assert!(!self.tier_shares.is_empty());
        let norm: f64 = self.tier_shares.iter().sum();
        let arrivals = self.arrivals.sample(self.duration_s, rng);
        let mut out = Vec::with_capacity(arrivals.len());
        for arrival_s in arrivals {
            let mut u = rng.next_f64() * norm;
            let mut tier = self.tier_shares.len() - 1;
            for (i, &share) in self.tier_shares.iter().enumerate() {
                if u < share {
                    tier = i;
                    break;
                }
                u -= share;
            }
            let (mut prompt, mut decode) = self.dataset.sample(rng);
            if let Some(cap) = self.max_prompt {
                prompt = prompt.min(cap);
            }
            if let Some(cap) = self.max_decode {
                decode = decode.min(cap);
            }
            let importance = if rng.chance(self.low_importance_frac) {
                Importance::Low
            } else {
                Importance::High
            };
            out.push(RequestSpec {
                arrival_s,
                prompt_tokens: prompt,
                decode_tokens: decode,
                tier,
                app_id: tier as u32,
                importance,
                session_id: None,
                prefix_tokens: 0,
            });
        }
        out
    }
}

/// Multi-turn session workload (chat/agent traffic). Each session is a
/// conversation: turn `k`'s prompt is the whole history so far (the
/// session prefix) plus the user's new message, so consecutive turns
/// re-submit an ever-growing shared prefix. The generator records that
/// overlap in [`RequestSpec::session_id`]/[`RequestSpec::prefix_tokens`]
/// so prefix-cache-aware serving can skip recomputing it; engines
/// without a cache simply re-prefill everything, which is the baseline.
///
/// Flash-crowd mode (`flash_frac` > 0) routes that fraction of sessions
/// through one shared "hot" system prompt (session id 0): their first
/// turns already share `hot_prompt_tokens` with each other, modelling a
/// popular assistant persona or a viral app template.
#[derive(Debug, Clone)]
pub struct SessionSpec {
    pub dataset: Dataset,
    /// Session start times (the arrival process counts sessions, not
    /// turns; at `mean_turns` turns each, turn QPS is that much higher).
    pub arrivals: ArrivalProcess,
    pub duration_s: f64,
    /// Mean turns per session; turn counts are geometric with support
    /// ≥ 1, matching the heavy tail of real conversation lengths.
    pub mean_turns: f64,
    /// Mean think time between a turn's last token and the next turn's
    /// arrival (exponential; 0 = immediate).
    pub mean_think_s: f64,
    /// Per-session QoS tier shares (a session keeps one tier for life).
    pub tier_shares: Vec<f64>,
    /// Fraction of sessions flagged low-importance.
    pub low_importance_frac: f64,
    /// Fraction of sessions in the flash crowd (shared session id 0).
    pub flash_frac: f64,
    /// Tokens of the shared hot system prompt flash sessions open with.
    pub hot_prompt_tokens: u32,
    pub max_prompt: Option<u32>,
    pub max_decode: Option<u32>,
}

/// Hard cap on turns per session: keeps a pathological geometric draw
/// from generating an unbounded conversation.
const MAX_TURNS: u32 = 64;

impl SessionSpec {
    /// Conversational defaults over the given dataset: session-level
    /// Poisson arrivals, equal tier thirds, no flash crowd.
    pub fn conversational(dataset: Dataset, sessions_per_s: f64, duration_s: f64) -> Self {
        SessionSpec {
            dataset,
            arrivals: ArrivalProcess::Poisson { qps: sessions_per_s },
            duration_s,
            mean_turns: 4.0,
            mean_think_s: 10.0,
            tier_shares: vec![1.0 / 3.0, 1.0 / 3.0, 1.0 / 3.0],
            low_importance_frac: 0.0,
            flash_frac: 0.0,
            hot_prompt_tokens: 1024,
            max_prompt: None,
            max_decode: None,
        }
    }

    /// Apply the `workload.session` config block on top of the
    /// conversational defaults.
    pub fn from_config(
        dataset: Dataset,
        sessions_per_s: f64,
        duration_s: f64,
        sc: &crate::config::SessionConfig,
    ) -> Self {
        let mut s = Self::conversational(dataset, sessions_per_s, duration_s);
        s.mean_turns = sc.mean_turns;
        s.mean_think_s = sc.mean_think_s;
        s.flash_frac = sc.flash_frac;
        s.hot_prompt_tokens = sc.hot_prompt_tokens;
        s
    }

    /// Generate the turn trace, sorted by arrival time. Turns arriving
    /// after `duration_s` are dropped (the workload window closes), so
    /// late-starting sessions may be truncated mid-conversation.
    pub fn generate(&self, rng: &mut Rng) -> Vec<RequestSpec> {
        assert!(!self.tier_shares.is_empty());
        assert!(self.mean_turns >= 1.0, "a session has at least one turn");
        let norm: f64 = self.tier_shares.iter().sum();
        // Geometric continuation: P(another turn) = 1 − 1/mean_turns
        // gives E[turns] = mean_turns with support ≥ 1.
        let cont_p = 1.0 - 1.0 / self.mean_turns;
        let starts = self.arrivals.sample(self.duration_s, rng);
        let mut out = Vec::with_capacity(starts.len() * self.mean_turns.ceil() as usize);
        let mut next_sid: u64 = 1;
        for start in starts {
            let flash = rng.chance(self.flash_frac);
            let sid = if flash {
                0
            } else {
                let s = next_sid;
                next_sid += 1;
                s
            };
            let mut u = rng.next_f64() * norm;
            let mut tier = self.tier_shares.len() - 1;
            for (i, &share) in self.tier_shares.iter().enumerate() {
                if u < share {
                    tier = i;
                    break;
                }
                u -= share;
            }
            let importance = if rng.chance(self.low_importance_frac) {
                Importance::Low
            } else {
                Importance::High
            };
            // The session's accumulated history: what the next turn
            // re-submits verbatim ahead of the new user message. Flash
            // sessions open on the shared hot prompt.
            let mut prefix: u32 = if flash { self.hot_prompt_tokens } else { 0 };
            let mut t = start;
            let mut turns = 1u32;
            loop {
                let (new_prompt, mut decode) = self.dataset.sample(rng);
                if let Some(cap) = self.max_decode {
                    decode = decode.min(cap);
                }
                let mut prompt = prefix.saturating_add(new_prompt).max(1);
                if let Some(cap) = self.max_prompt {
                    prompt = prompt.min(cap);
                }
                // The claim must leave at least one token of fresh
                // prefill (the engine caps hits the same way).
                let claim = prefix.min(prompt.saturating_sub(1));
                out.push(RequestSpec {
                    arrival_s: t,
                    prompt_tokens: prompt,
                    decode_tokens: decode,
                    tier,
                    app_id: tier as u32,
                    importance,
                    session_id: Some(sid),
                    prefix_tokens: claim,
                });
                if turns >= MAX_TURNS || !rng.chance(cont_p) {
                    break;
                }
                turns += 1;
                // Next turn re-submits everything said so far.
                prefix = prompt.saturating_add(decode);
                let think = if self.mean_think_s > 0.0 {
                    rng.exponential(1.0 / self.mean_think_s)
                } else {
                    0.0
                };
                t += think;
                if t >= self.duration_s {
                    break;
                }
            }
        }
        out.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_matches() {
        let mut rng = Rng::new(1);
        let arrivals = ArrivalProcess::Poisson { qps: 5.0 }.sample(2000.0, &mut rng);
        let rate = arrivals.len() as f64 / 2000.0;
        assert!((rate - 5.0).abs() < 0.2, "rate {rate}");
    }

    #[test]
    fn arrivals_sorted_and_bounded() {
        let mut rng = Rng::new(2);
        let arrivals = ArrivalProcess::Poisson { qps: 3.0 }.sample(100.0, &mut rng);
        for w in arrivals.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(arrivals.iter().all(|&t| (0.0..100.0).contains(&t)));
    }

    #[test]
    fn diurnal_alternates_rate() {
        let mut rng = Rng::new(3);
        let p = ArrivalProcess::Diurnal { low_qps: 2.0, high_qps: 6.0, period_s: 900.0 };
        let arrivals = p.sample(3600.0, &mut rng);
        let in_first_low = arrivals.iter().filter(|&&t| t < 900.0).count() as f64 / 900.0;
        let in_first_high =
            arrivals.iter().filter(|&&t| (900.0..1800.0).contains(&t)).count() as f64 / 900.0;
        assert!((in_first_low - 2.0).abs() < 0.5, "low {in_first_low}");
        assert!((in_first_high - 6.0).abs() < 0.8, "high {in_first_high}");
    }

    #[test]
    fn burst_window_rate() {
        let mut rng = Rng::new(4);
        let p = ArrivalProcess::Burst {
            base_qps: 1.0,
            burst_qps: 10.0,
            burst_start_s: 100.0,
            burst_end_s: 200.0,
        };
        let arrivals = p.sample(300.0, &mut rng);
        let burst = arrivals.iter().filter(|&&t| (100.0..200.0).contains(&t)).count();
        let outside = arrivals.len() - burst;
        assert!(burst > 800 && burst < 1200, "burst {burst}");
        assert!(outside > 120 && outside < 280, "outside {outside}");
    }

    #[test]
    fn tier_shares_respected() {
        let mut rng = Rng::new(5);
        let spec = WorkloadSpec::uniform(Dataset::sharegpt(), 20.0, 1000.0);
        let reqs = spec.generate(&mut rng);
        let n = reqs.len() as f64;
        for tier in 0..3 {
            let frac = reqs.iter().filter(|r| r.tier == tier).count() as f64 / n;
            assert!((frac - 1.0 / 3.0).abs() < 0.03, "tier {tier}: {frac}");
        }
        // app id mirrors tier in this setup
        assert!(reqs.iter().all(|r| r.app_id == r.tier as u32));
    }

    #[test]
    fn importance_fraction() {
        let mut rng = Rng::new(6);
        let mut spec = WorkloadSpec::uniform(Dataset::azure_code(), 20.0, 1000.0);
        spec.low_importance_frac = 0.2;
        let reqs = spec.generate(&mut rng);
        let low =
            reqs.iter().filter(|r| r.importance == Importance::Low).count() as f64
                / reqs.len() as f64;
        assert!((low - 0.2).abs() < 0.03, "low frac {low}");
    }

    #[test]
    fn caps_are_applied() {
        let mut rng = Rng::new(7);
        let mut spec = WorkloadSpec::uniform(Dataset::sharegpt(), 10.0, 500.0);
        spec.max_prompt = Some(512);
        spec.max_decode = Some(64);
        let reqs = spec.generate(&mut rng);
        assert!(reqs.iter().all(|r| r.prompt_tokens <= 512 && r.decode_tokens <= 64));
    }

    #[test]
    fn deterministic_for_seed() {
        let spec = WorkloadSpec::uniform(Dataset::azure_conv(), 5.0, 200.0);
        let a = spec.generate(&mut Rng::new(42));
        let b = spec.generate(&mut Rng::new(42));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
        }
    }

    fn turns_of(trace: &[RequestSpec], sid: u64) -> Vec<&RequestSpec> {
        trace.iter().filter(|r| r.session_id == Some(sid)).collect()
    }

    #[test]
    fn session_turns_extend_the_prefix() {
        let mut rng = Rng::new(11);
        let spec = SessionSpec::conversational(Dataset::sharegpt(), 0.5, 600.0);
        let trace = spec.generate(&mut rng);
        assert!(!trace.is_empty());
        let max_sid = trace.iter().filter_map(|r| r.session_id).max().unwrap();
        let mut multi = 0;
        for sid in 1..=max_sid {
            let turns = turns_of(&trace, sid);
            // Unique sessions start cold…
            assert_eq!(turns[0].prefix_tokens, 0, "session {sid} turn 0 must be cold");
            // …and each later turn re-submits at least the whole
            // previous turn (prompt + its decode) as prefix.
            for w in turns.windows(2) {
                assert!(w[0].arrival_s <= w[1].arrival_s);
                let grown = w[0].prompt_tokens + w[0].decode_tokens;
                assert_eq!(
                    w[1].prefix_tokens,
                    grown.min(w[1].prompt_tokens - 1),
                    "session {sid}: turn prefix must be the prior history"
                );
                assert!(w[1].prompt_tokens > w[1].prefix_tokens);
            }
            if turns.len() > 1 {
                multi += 1;
            }
            // One tier, one importance per session.
            assert!(turns.iter().all(|r| r.tier == turns[0].tier));
            assert!(turns.iter().all(|r| r.importance == turns[0].importance));
        }
        assert!(multi > 0, "mean_turns 4 must yield multi-turn sessions");
    }

    #[test]
    fn session_trace_is_sorted_and_bounded() {
        let mut rng = Rng::new(12);
        let spec = SessionSpec::conversational(Dataset::azure_conv(), 1.0, 300.0);
        let trace = spec.generate(&mut rng);
        for w in trace.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
        assert!(trace.iter().all(|r| (0.0..300.0).contains(&r.arrival_s)));
        assert!(trace.iter().all(|r| r.prefix_tokens < r.prompt_tokens));
    }

    #[test]
    fn flash_sessions_share_the_hot_prompt() {
        let mut rng = Rng::new(13);
        let mut spec = SessionSpec::conversational(Dataset::sharegpt(), 1.0, 400.0);
        spec.flash_frac = 0.5;
        spec.hot_prompt_tokens = 1024;
        let trace = spec.generate(&mut rng);
        let flash = turns_of(&trace, 0);
        assert!(!flash.is_empty(), "half the sessions must be flash");
        // Every flash turn claims at least the hot prompt as prefix and
        // carries it in the prompt itself.
        assert!(flash.iter().all(|r| r.prefix_tokens >= 1024.min(r.prompt_tokens - 1)));
        assert!(flash.iter().all(|r| r.prompt_tokens > 1024));
        // Non-flash traffic still exists and stays cold on turn 0.
        let max_sid = trace.iter().filter_map(|r| r.session_id).max().unwrap();
        assert!(max_sid >= 1, "non-flash sessions must keep unique ids");
    }

    #[test]
    fn mean_turns_one_yields_single_turn_sessions() {
        let mut rng = Rng::new(14);
        let mut spec = SessionSpec::conversational(Dataset::azure_code(), 2.0, 200.0);
        spec.mean_turns = 1.0;
        let trace = spec.generate(&mut rng);
        let max_sid = trace.iter().filter_map(|r| r.session_id).max().unwrap();
        for sid in 1..=max_sid {
            assert_eq!(turns_of(&trace, sid).len(), 1);
        }
        assert!(trace.iter().all(|r| r.prefix_tokens == 0));
    }

    #[test]
    fn session_generation_is_deterministic() {
        let mut spec = SessionSpec::conversational(Dataset::sharegpt(), 1.5, 300.0);
        spec.flash_frac = 0.3;
        let a = spec.generate(&mut Rng::new(99));
        let b = spec.generate(&mut Rng::new(99));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
            assert_eq!(x.session_id, y.session_id);
            assert_eq!(x.prefix_tokens, y.prefix_tokens);
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
        }
    }
}
