//! Dataset token-length models fit to the paper's Table 1.
//!
//! | Dataset    | prompt p50 | prompt p90 | decode p50 | decode p90 |
//! |------------|-----------:|-----------:|-----------:|-----------:|
//! | ShareGPT   |       1730 |       5696 |        415 |        834 |
//! | Azure Conv |        928 |       3830 |         41 |        342 |
//! | Azure Code |       1930 |       6251 |          8 |         43 |
//!
//! Prompt and decode lengths are modelled as independent lognormals with
//! parameters derived from (p50, p90) — `util::rng::lognormal_from_quantiles`.
//! Lognormals are the standard fit for LLM trace length distributions and
//! match the heavy right tail the paper's fairness analysis (long vs short
//! requests, §4.2) depends on.

use crate::util::rng::{lognormal_from_quantiles, Rng};

/// Table 1 row: quantile statistics of a dataset.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TokenStats {
    pub p50: f64,
    pub p90: f64,
}

/// A synthetic dataset calibrated to published statistics.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: &'static str,
    pub prompt: TokenStats,
    pub decode: TokenStats,
    prompt_mu: f64,
    prompt_sigma: f64,
    decode_mu: f64,
    decode_sigma: f64,
}

impl Dataset {
    pub fn new(name: &'static str, prompt: TokenStats, decode: TokenStats) -> Self {
        let (pm, ps) = lognormal_from_quantiles(prompt.p50, prompt.p90);
        let (dm, ds) = lognormal_from_quantiles(decode.p50, decode.p90);
        Dataset {
            name,
            prompt,
            decode,
            prompt_mu: pm,
            prompt_sigma: ps,
            decode_mu: dm,
            decode_sigma: ds,
        }
    }

    /// ShareGPT [Table 1].
    pub fn sharegpt() -> Self {
        Self::new(
            "sharegpt",
            TokenStats { p50: 1730.0, p90: 5696.0 },
            TokenStats { p50: 415.0, p90: 834.0 },
        )
    }

    /// Azure conversation trace [Table 1].
    pub fn azure_conv() -> Self {
        Self::new(
            "azure-conv",
            TokenStats { p50: 928.0, p90: 3830.0 },
            TokenStats { p50: 41.0, p90: 342.0 },
        )
    }

    /// Azure code-completion trace [Table 1].
    pub fn azure_code() -> Self {
        Self::new(
            "azure-code",
            TokenStats { p50: 1930.0, p90: 6251.0 },
            TokenStats { p50: 8.0, p90: 43.0 },
        )
    }

    pub fn by_name(name: &str) -> Option<Dataset> {
        match name {
            "sharegpt" => Some(Self::sharegpt()),
            "azure-conv" => Some(Self::azure_conv()),
            "azure-code" => Some(Self::azure_code()),
            _ => None,
        }
    }

    pub fn all() -> Vec<Dataset> {
        vec![Self::sharegpt(), Self::azure_conv(), Self::azure_code()]
    }

    /// Sample one (prompt_tokens, decode_tokens) pair. Lengths are
    /// clamped to >= 1 (every request has a prompt and emits at least one
    /// token).
    pub fn sample(&self, rng: &mut Rng) -> (u32, u32) {
        let p = rng.lognormal(self.prompt_mu, self.prompt_sigma).round().max(1.0);
        let d = rng.lognormal(self.decode_mu, self.decode_sigma).round().max(1.0);
        (p as u32, d as u32)
    }

    /// The 90th-percentile prompt threshold used by the paper's
    /// long-vs-short fairness split (§4.2).
    pub fn long_prompt_threshold(&self) -> u32 {
        self.prompt.p90 as u32
    }

    /// Mean prompt length of the lognormal fit (capacity planning).
    pub fn mean_prompt(&self) -> f64 {
        (self.prompt_mu + 0.5 * self.prompt_sigma * self.prompt_sigma).exp()
    }

    pub fn mean_decode(&self) -> f64 {
        (self.decode_mu + 0.5 * self.decode_sigma * self.decode_sigma).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_quantiles(ds: &Dataset) {
        let mut rng = Rng::new(99);
        let n = 100_000;
        let mut prompts: Vec<f64> = Vec::with_capacity(n);
        let mut decodes: Vec<f64> = Vec::with_capacity(n);
        for _ in 0..n {
            let (p, d) = ds.sample(&mut rng);
            prompts.push(p as f64);
            decodes.push(d as f64);
        }
        prompts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        decodes.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |v: &[f64], q: f64| v[(q * (n - 1) as f64) as usize];
        // Empirical quantiles within 6% of Table 1 targets.
        assert!(
            (q(&prompts, 0.5) / ds.prompt.p50 - 1.0).abs() < 0.06,
            "{} prompt p50: {}",
            ds.name,
            q(&prompts, 0.5)
        );
        assert!(
            (q(&prompts, 0.9) / ds.prompt.p90 - 1.0).abs() < 0.06,
            "{} prompt p90: {}",
            ds.name,
            q(&prompts, 0.9)
        );
        assert!(
            (q(&decodes, 0.5) / ds.decode.p50 - 1.0).abs() < 0.12,
            "{} decode p50: {}",
            ds.name,
            q(&decodes, 0.5)
        );
        assert!(
            (q(&decodes, 0.9) / ds.decode.p90 - 1.0).abs() < 0.12,
            "{} decode p90: {}",
            ds.name,
            q(&decodes, 0.9)
        );
    }

    #[test]
    fn sharegpt_matches_table1() {
        check_quantiles(&Dataset::sharegpt());
    }

    #[test]
    fn azure_conv_matches_table1() {
        check_quantiles(&Dataset::azure_conv());
    }

    #[test]
    fn azure_code_matches_table1() {
        check_quantiles(&Dataset::azure_code());
    }

    #[test]
    fn lengths_at_least_one() {
        let ds = Dataset::azure_code(); // tiny decode lengths stress this
        let mut rng = Rng::new(1);
        for _ in 0..10_000 {
            let (p, d) = ds.sample(&mut rng);
            assert!(p >= 1 && d >= 1);
        }
    }

    #[test]
    fn by_name_round_trips() {
        for ds in Dataset::all() {
            assert_eq!(Dataset::by_name(ds.name).unwrap().name, ds.name);
        }
        assert!(Dataset::by_name("nope").is_none());
    }

    #[test]
    fn means_exceed_medians() {
        // Lognormal: mean > median (right skew) — the property the
        // long-request fairness analysis leans on.
        for ds in Dataset::all() {
            assert!(ds.mean_prompt() > ds.prompt.p50);
            assert!(ds.mean_decode() > ds.decode.p50);
        }
    }
}
