//! QoS classes, SLO targets and deadline arithmetic (paper §3.2).
//!
//! Niyama defines two QoS *classes* — interactive (TTFT + TBT SLOs) and
//! non-interactive (TTLT SLO) — and lets applications declare arbitrary
//! *tiers* within them (Table 2). All deadline math from eqs. (1)–(3)
//! lives here. Times are f64 seconds on a workload-relative clock.

/// Service-level objectives of a QoS class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Slo {
    /// User-facing: deadline on the first token and on every subsequent
    /// token gap.
    Interactive {
        /// Time-to-first-token target, seconds.
        ttft_s: f64,
        /// Time-between-tokens target, seconds.
        tbt_s: f64,
    },
    /// Batch-oriented: a single deadline on total completion.
    NonInteractive {
        /// Time-to-last-token target, seconds.
        ttlt_s: f64,
    },
}

impl Slo {
    pub fn is_interactive(&self) -> bool {
        matches!(self, Slo::Interactive { .. })
    }

    /// Seconds from arrival to this SLO's binding first deadline, plus
    /// whether decode work counts against it: `(TTFT, false)` for
    /// interactive requests (first service meets it) and `(TTLT, true)`
    /// for non-interactive ones, whose single deadline covers the whole
    /// decode tail. The cluster dispatcher and the relegation-handoff
    /// feasibility check both price requests with this one rule.
    pub fn deadline_budget(&self) -> (f64, bool) {
        match *self {
            Slo::Interactive { ttft_s, .. } => (ttft_s, false),
            Slo::NonInteractive { ttlt_s } => (ttlt_s, true),
        }
    }
}

/// A QoS tier: a named SLO an application signs up for.
#[derive(Debug, Clone)]
pub struct QosTier {
    pub name: String,
    pub slo: Slo,
}

impl QosTier {
    pub fn interactive(name: &str, ttft_s: f64, tbt_s: f64) -> Self {
        QosTier { name: name.to_string(), slo: Slo::Interactive { ttft_s, tbt_s } }
    }

    pub fn non_interactive(name: &str, ttlt_s: f64) -> Self {
        QosTier { name: name.to_string(), slo: Slo::NonInteractive { ttlt_s } }
    }
}

/// Resolve a request's tier index against a tier table, clamping
/// out-of-range indices to the loosest tier. Admission, dispatch and
/// load snapshots all resolve SLOs through this one function so a
/// request can never be priced against a different SLO than it is
/// admitted under.
pub fn slo_for_tier(tiers: &[QosTier], tier: usize) -> Slo {
    tiers[tier.min(tiers.len() - 1)].slo
}

/// The paper's Table 2 tiers: Q1 interactive (TTFT 6 s, TBT 50 ms),
/// Q2 non-interactive (TTLT 600 s), Q3 non-interactive (TTLT 1800 s).
pub fn table2_tiers() -> Vec<QosTier> {
    vec![
        QosTier::interactive("Q1", 6.0, 0.050),
        QosTier::non_interactive("Q2", 600.0),
        QosTier::non_interactive("Q3", 1800.0),
    ]
}

/// Deadline calculator for one request under a given SLO.
#[derive(Debug, Clone, Copy)]
pub struct Deadlines {
    pub arrival_s: f64,
    pub slo: Slo,
}

impl Deadlines {
    pub fn new(arrival_s: f64, slo: Slo) -> Self {
        Deadlines { arrival_s, slo }
    }

    /// Eq. (1): D_first = t_arrival + SLO_TTFT. For non-interactive
    /// requests the first token has no deadline of its own; we return the
    /// TTLT deadline (the only constraint that exists).
    pub fn first_token(&self) -> f64 {
        match self.slo {
            Slo::Interactive { ttft_s, .. } => self.arrival_s + ttft_s,
            Slo::NonInteractive { ttlt_s } => self.arrival_s + ttlt_s,
        }
    }

    /// Eq. (2): D_n = t_arrival + SLO_TTFT + (n-1) * SLO_TBT for the n-th
    /// token (1-based) of an interactive request. For non-interactive
    /// requests, per-token pacing is derived by `paced_token_deadline`.
    pub fn token(&self, n: u32) -> f64 {
        debug_assert!(n >= 1);
        match self.slo {
            Slo::Interactive { ttft_s, tbt_s } => {
                self.arrival_s + ttft_s + (n as f64 - 1.0) * tbt_s
            }
            Slo::NonInteractive { ttlt_s } => self.arrival_s + ttlt_s,
        }
    }

    /// Eq. (3): D_total = t_arrival + SLO_TTLT. Interactive requests'
    /// completion deadline is the deadline of their final token, which
    /// depends on output length; this returns the deadline assuming
    /// `total_tokens` outputs.
    pub fn total(&self, total_tokens: u32) -> f64 {
        match self.slo {
            Slo::Interactive { .. } => self.token(total_tokens.max(1)),
            Slo::NonInteractive { ttlt_s } => self.arrival_s + ttlt_s,
        }
    }

    /// Implicit per-token pacing deadline for a non-interactive request in
    /// decode phase (DESIGN.md §4): spread the remaining time budget evenly
    /// over the expected remaining tokens, so slack is consumable by
    /// dynamic chunking without jeopardizing the TTLT target.
    ///
    /// `now` is the current time, `remaining_tokens` the expected number of
    /// tokens still to emit (>= 1).
    pub fn paced_token_deadline(&self, now: f64, remaining_tokens: u32) -> f64 {
        match self.slo {
            Slo::Interactive { .. } => unreachable!("pacing is for non-interactive"),
            Slo::NonInteractive { ttlt_s } => {
                let total_deadline = self.arrival_s + ttlt_s;
                let budget = total_deadline - now;
                now + budget / remaining_tokens.max(1) as f64
            }
        }
    }
}

/// Application-provided importance hint for relegation (paper §3.4:
/// "free vs paid tier").
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Importance {
    /// Relegate first under overload.
    Low = 0,
    /// Preserve for as long as possible.
    High = 1,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matches_paper() {
        let tiers = table2_tiers();
        assert_eq!(tiers.len(), 3);
        assert_eq!(tiers[0].slo, Slo::Interactive { ttft_s: 6.0, tbt_s: 0.050 });
        assert_eq!(tiers[1].slo, Slo::NonInteractive { ttlt_s: 600.0 });
        assert_eq!(tiers[2].slo, Slo::NonInteractive { ttlt_s: 1800.0 });
    }

    #[test]
    fn eq1_first_token_deadline() {
        let d = Deadlines::new(10.0, Slo::Interactive { ttft_s: 6.0, tbt_s: 0.05 });
        assert_eq!(d.first_token(), 16.0);
    }

    #[test]
    fn eq2_token_deadlines_step_by_tbt() {
        let d = Deadlines::new(0.0, Slo::Interactive { ttft_s: 6.0, tbt_s: 0.05 });
        assert_eq!(d.token(1), 6.0);
        assert!((d.token(2) - 6.05).abs() < 1e-12);
        assert!((d.token(11) - 6.5).abs() < 1e-12);
    }

    #[test]
    fn eq3_total_deadline() {
        let d = Deadlines::new(5.0, Slo::NonInteractive { ttlt_s: 600.0 });
        assert_eq!(d.total(1000), 605.0);
        assert_eq!(d.first_token(), 605.0);
        assert_eq!(d.token(7), 605.0);
    }

    #[test]
    fn interactive_total_depends_on_length() {
        let d = Deadlines::new(0.0, Slo::Interactive { ttft_s: 1.0, tbt_s: 0.1 });
        assert!((d.total(11) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pacing_splits_budget_evenly() {
        let d = Deadlines::new(0.0, Slo::NonInteractive { ttlt_s: 100.0 });
        // At t=0 with 10 tokens left: next token due at 10 s.
        assert!((d.paced_token_deadline(0.0, 10) - 10.0).abs() < 1e-12);
        // At t=90 with 1 token left: due at the TTLT deadline.
        assert!((d.paced_token_deadline(90.0, 1) - 100.0).abs() < 1e-12);
    }

    #[test]
    fn pacing_past_deadline_is_in_the_past() {
        let d = Deadlines::new(0.0, Slo::NonInteractive { ttlt_s: 10.0 });
        // Already past TTLT: the paced deadline must not extend it.
        assert!(d.paced_token_deadline(20.0, 5) < 20.0);
    }

    #[test]
    fn importance_orders() {
        assert!(Importance::Low < Importance::High);
    }

    #[test]
    fn slo_for_tier_clamps_out_of_range() {
        let tiers = table2_tiers();
        assert_eq!(slo_for_tier(&tiers, 0), tiers[0].slo);
        assert_eq!(slo_for_tier(&tiers, 99), tiers[2].slo);
    }

    #[test]
    fn deadline_budget_rule() {
        let int = Slo::Interactive { ttft_s: 6.0, tbt_s: 0.05 };
        assert_eq!(int.deadline_budget(), (6.0, false));
        let batch = Slo::NonInteractive { ttlt_s: 600.0 };
        assert_eq!(batch.deadline_budget(), (600.0, true));
    }
}
