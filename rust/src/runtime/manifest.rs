//! AOT artifact manifest (`artifacts/manifest.json`), written by
//! `python/compile/aot.py`. Describes the model, the parameter contract
//! and the executable variants available to the runtime.

use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub param_count: usize,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExecutableKind {
    /// Prefill chunk of the given bucket size.
    Prefill { chunk: usize },
    /// Batched decode step of the given batch bucket.
    Decode { batch: usize },
}

#[derive(Debug, Clone)]
pub struct ExecutableEntry {
    pub name: String,
    pub kind: ExecutableKind,
    pub file: PathBuf,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelInfo,
    pub params_file: PathBuf,
    pub param_order: Vec<String>,
    /// Per-sequence KV cache shape: (L, 2, Hkv, S, D).
    pub kv_cache_shape: Vec<usize>,
    pub executables: Vec<ExecutableEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let version = j
            .get("format_version")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("manifest missing format_version"))?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }

        let m = j.get("model").ok_or_else(|| anyhow!("manifest missing model"))?;
        let field = |key: &str| -> Result<usize> {
            m.get(key)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("model missing '{key}'"))
        };
        let model = ModelInfo {
            vocab_size: field("vocab_size")?,
            d_model: field("d_model")?,
            n_layers: field("n_layers")?,
            n_heads: field("n_heads")?,
            n_kv_heads: field("n_kv_heads")?,
            head_dim: field("head_dim")?,
            d_ff: field("d_ff")?,
            max_seq: field("max_seq")?,
            param_count: field("param_count")?,
        };

        let params_file = dir.join(
            j.get("params_file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("manifest missing params_file"))?,
        );

        let param_order = j
            .get("param_order")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest missing param_order"))?
            .iter()
            .map(|v| v.as_str().map(str::to_string).ok_or_else(|| anyhow!("bad param name")))
            .collect::<Result<Vec<_>>>()?;

        let kv_cache_shape = j
            .get("kv_cache_shape")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest missing kv_cache_shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad kv dim")))
            .collect::<Result<Vec<_>>>()?;
        if kv_cache_shape.len() != 5 {
            bail!("kv_cache_shape must have 5 dims (L,2,Hkv,S,D)");
        }

        let mut executables = Vec::new();
        for e in j
            .get("executables")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest missing executables"))?
        {
            let name = e
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("executable missing name"))?
                .to_string();
            let file = dir.join(
                e.get("file")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("executable missing file"))?,
            );
            let kind = match e.get("kind").and_then(|v| v.as_str()) {
                Some("prefill") => ExecutableKind::Prefill {
                    chunk: e
                        .get("chunk")
                        .and_then(|v| v.as_usize())
                        .ok_or_else(|| anyhow!("prefill missing chunk"))?,
                },
                Some("decode") => ExecutableKind::Decode {
                    batch: e
                        .get("batch")
                        .and_then(|v| v.as_usize())
                        .ok_or_else(|| anyhow!("decode missing batch"))?,
                },
                other => bail!("unknown executable kind {other:?}"),
            };
            executables.push(ExecutableEntry { name, kind, file });
        }
        if executables.is_empty() {
            bail!("manifest lists no executables");
        }

        Ok(Manifest { model, params_file, param_order, kv_cache_shape, executables })
    }

    /// Elements in one sequence's KV cache.
    pub fn kv_elements(&self) -> usize {
        self.kv_cache_shape.iter().product()
    }

    /// Sorted available prefill chunk buckets.
    pub fn chunk_buckets(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .executables
            .iter()
            .filter_map(|e| match e.kind {
                ExecutableKind::Prefill { chunk } => Some(chunk),
                _ => None,
            })
            .collect();
        v.sort_unstable();
        v
    }

    /// Sorted available decode batch buckets.
    pub fn decode_buckets(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .executables
            .iter()
            .filter_map(|e| match e.kind {
                ExecutableKind::Decode { batch } => Some(batch),
                _ => None,
            })
            .collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "format_version": 1,
        "model": {"vocab_size": 8192, "d_model": 256, "n_layers": 4,
                   "n_heads": 8, "n_kv_heads": 4, "head_dim": 32,
                   "d_ff": 768, "max_seq": 640, "param_count": 7342336},
        "params_file": "params.bin",
        "param_order": ["embed", "final_norm", "lm_head"],
        "kv_cache_shape": [4, 2, 4, 640, 32],
        "executables": [
            {"name": "prefill_c16", "kind": "prefill", "chunk": 16, "file": "prefill_c16.hlo.txt"},
            {"name": "prefill_c256", "kind": "prefill", "chunk": 256, "file": "prefill_c256.hlo.txt"},
            {"name": "decode_b1", "kind": "decode", "batch": 1, "file": "decode_b1.hlo.txt"},
            {"name": "decode_b8", "kind": "decode", "batch": 8, "file": "decode_b8.hlo.txt"}
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/artifacts")).unwrap();
        assert_eq!(m.model.vocab_size, 8192);
        assert_eq!(m.kv_elements(), 4 * 2 * 4 * 640 * 32);
        assert_eq!(m.chunk_buckets(), vec![16, 256]);
        assert_eq!(m.decode_buckets(), vec![1, 8]);
        assert!(m.params_file.ends_with("params.bin"));
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"format_version\": 1", "\"format_version\": 9");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_wrong_kv_rank() {
        let bad = SAMPLE.replace("[4, 2, 4, 640, 32]", "[4, 2, 4]");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn parses_real_artifact_manifest_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.model.param_count > 1_000_000);
            assert!(!m.chunk_buckets().is_empty());
            assert!(!m.decode_buckets().is_empty());
        }
    }
}
