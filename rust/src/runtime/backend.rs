//! PJRT execution backend: the real-model counterpart of `SimBackend`.
//!
//! Executes the engine's batches on the AOT-compiled model, measures
//! wall-clock iteration latency, and collects the actually generated
//! tokens per request (greedy sampling). The scheduler neither knows nor
//! cares which backend is underneath — that symmetry is the point.

use super::client::{argmax, ModelRuntime};
use crate::engine::{ExecutionBackend, IterationResult};
use crate::kv::KvStore;
use crate::request::{RequestId, RequestStore};
use crate::scheduler::Batch;
use std::collections::HashMap;
use std::time::Instant;

pub struct PjrtBackend {
    runtime: ModelRuntime,
    kv: KvStore,
    /// Prompt token ids per request (provided at submission).
    prompts: HashMap<RequestId, Vec<i32>>,
    /// Generated token ids per request.
    generated: HashMap<RequestId, Vec<i32>>,
    /// Next input token for decode (last sampled token).
    next_token: HashMap<RequestId, i32>,
    /// Measured (batch shape, latency) samples for predictor fitting.
    pub samples: Vec<(crate::simulator::BatchShape, f64)>,
}

impl PjrtBackend {
    pub fn new(runtime: ModelRuntime) -> Self {
        let kv_elems = runtime.kv_elements();
        PjrtBackend {
            runtime,
            kv: KvStore::new(kv_elems),
            prompts: HashMap::new(),
            generated: HashMap::new(),
            next_token: HashMap::new(),
            samples: Vec::new(),
        }
    }

    pub fn runtime(&self) -> &ModelRuntime {
        &self.runtime
    }

    /// Register the actual prompt tokens for a request (the trace only
    /// carries lengths; the server path carries real ids).
    pub fn set_prompt(&mut self, id: RequestId, tokens: Vec<i32>) {
        self.prompts.insert(id, tokens);
    }

    /// Synthesize a deterministic prompt of the given length (examples /
    /// load-generation without a tokenizer).
    pub fn synth_prompt(&mut self, id: RequestId, len: u32, seed: u64) {
        let vocab = self.runtime.vocab_size() as u64;
        let mut rng = crate::util::Rng::new(seed ^ (id as u64).wrapping_mul(0x9E37));
        let tokens: Vec<i32> = (0..len).map(|_| (rng.below(vocab)) as i32).collect();
        self.prompts.insert(id, tokens);
    }

    pub fn generated(&self, id: RequestId) -> Option<&[i32]> {
        self.generated.get(&id).map(|v| v.as_slice())
    }

    /// Remove and return a finished request's generated tokens.
    pub fn take_generated(&mut self, id: RequestId) -> Option<Vec<i32>> {
        self.generated.remove(&id)
    }

    fn run_prefill_segment(&mut self, id: RequestId, tokens: u32, store: &RequestStore) {
        let req = store.get(id);
        let start = req.prefilled as usize;
        let prompt = self
            .prompts
            .get(&id)
            .unwrap_or_else(|| panic!("no prompt registered for request {id}"))
            .clone();
        let end = (start + tokens as usize).min(prompt.len());
        // Split into compiled bucket sizes.
        let max_chunk = self.runtime.max_chunk();
        let mut cursor = start;
        while cursor < end {
            let take = (end - cursor).min(max_chunk);
            let chunk = &prompt[cursor..cursor + take];
            let kv = self.kv.entry(id);
            let logits = self
                .runtime
                .prefill(kv, chunk, cursor)
                .expect("prefill execution failed");
            cursor += take;
            if cursor == prompt.len() {
                // Final chunk: sample the first output token.
                let tok = argmax(&logits);
                self.generated.entry(id).or_default().push(tok);
                self.next_token.insert(id, tok);
            }
        }
    }

    fn run_decodes(&mut self, ids: &[RequestId], store: &RequestStore) {
        let max_b = self.runtime.max_decode_batch();
        for group in ids.chunks(max_b) {
            let tokens: Vec<i32> =
                group.iter().map(|id| *self.next_token.get(id).expect("no next token")).collect();
            // Position of the token being fed in: the cache holds the
            // prompt plus all *previous* outputs; the most recent output
            // token is written by this very step. kv_tokens() counts
            // prefilled + decoded, so the input token's position is one
            // less.
            let positions: Vec<usize> =
                group.iter().map(|&id| store.get(id).kv_tokens() as usize - 1).collect();
            let mut kvs = self.kv.get_many_mut(group);
            let logits = self
                .runtime
                .decode(&mut kvs, &tokens, &positions)
                .expect("decode execution failed");
            drop(kvs);
            for (i, &id) in group.iter().enumerate() {
                let tok = argmax(&logits[i]);
                self.generated.entry(id).or_default().push(tok);
                self.next_token.insert(id, tok);
            }
        }
    }
}

impl ExecutionBackend for PjrtBackend {
    fn execute(&mut self, batch: &Batch, store: &RequestStore) -> IterationResult {
        let t0 = Instant::now();
        for w in &batch.prefill {
            self.run_prefill_segment(w.id, w.tokens, store);
        }
        if !batch.decodes.is_empty() {
            self.run_decodes(&batch.decodes, store);
        }
        let latency_s = t0.elapsed().as_secs_f64();
        self.samples.push((batch.shape(store), latency_s));
        IterationResult { latency_s }
    }

    fn release(&mut self, id: RequestId) {
        self.kv.release(id);
        self.prompts.remove(&id);
        self.next_token.remove(&id);
        // `generated` is kept: callers read transcripts after completion.
    }
}
