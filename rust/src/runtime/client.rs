//! PJRT model runtime: loads the AOT artifacts and executes them.
//!
//! One `ModelRuntime` owns a PJRT CPU client, the weight buffers (uploaded
//! once, device-resident for the process lifetime) and one compiled
//! executable per chunk-size / batch-size bucket. Python is never
//! involved: the HLO text produced by `python/compile/aot.py` is the
//! entire model.

use super::manifest::{ExecutableKind, Manifest};
use super::params::load_params;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::path::Path;

pub struct ModelRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    /// Weight buffers in contract order, uploaded once.
    param_bufs: Vec<xla::PjRtBuffer>,
    prefill_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    decode_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
}

impl ModelRuntime {
    /// Load manifest + params + every executable from an artifacts dir.
    pub fn load(dir: &Path) -> Result<ModelRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt client: {e:?}"))?;

        // Upload weights once.
        let tensors = load_params(&manifest.params_file)?;
        if tensors.len() != manifest.param_order.len() {
            bail!(
                "params.bin has {} tensors, manifest expects {}",
                tensors.len(),
                manifest.param_order.len()
            );
        }
        let mut param_bufs = Vec::with_capacity(tensors.len());
        for (tensor, want) in tensors.iter().zip(&manifest.param_order) {
            if &tensor.name != want {
                bail!("param order mismatch: {} vs {}", tensor.name, want);
            }
            let data = tensor.as_f32()?;
            let buf = client
                .buffer_from_host_buffer::<f32>(&data, &tensor.dims, None)
                .map_err(|e| anyhow!("uploading {}: {e:?}", tensor.name))?;
            param_bufs.push(buf);
        }

        // Compile all executables.
        let mut prefill_exes = BTreeMap::new();
        let mut decode_exes = BTreeMap::new();
        for entry in &manifest.executables {
            let proto = xla::HloModuleProto::from_text_file(&entry.file)
                .map_err(|e| anyhow!("parsing {}: {e:?}", entry.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", entry.name))?;
            match entry.kind {
                ExecutableKind::Prefill { chunk } => {
                    prefill_exes.insert(chunk, exe);
                }
                ExecutableKind::Decode { batch } => {
                    decode_exes.insert(batch, exe);
                }
            }
        }

        Ok(ModelRuntime { client, manifest, param_bufs, prefill_exes, decode_exes })
    }

    /// Elements in one sequence's KV cache.
    pub fn kv_elements(&self) -> usize {
        self.manifest.kv_elements()
    }

    pub fn vocab_size(&self) -> usize {
        self.manifest.model.vocab_size
    }

    pub fn max_seq(&self) -> usize {
        self.manifest.model.max_seq
    }

    /// Largest compiled chunk bucket.
    pub fn max_chunk(&self) -> usize {
        *self.prefill_exes.keys().last().expect("at least one prefill bucket")
    }

    /// Largest compiled decode batch bucket.
    pub fn max_decode_batch(&self) -> usize {
        *self.decode_exes.keys().last().expect("at least one decode bucket")
    }

    /// Smallest chunk bucket >= `len` (or the largest bucket if `len`
    /// exceeds all buckets — caller must split beforehand).
    fn chunk_bucket(&self, len: usize) -> usize {
        for (&b, _) in &self.prefill_exes {
            if b >= len {
                return b;
            }
        }
        self.max_chunk()
    }

    fn decode_bucket(&self, n: usize) -> usize {
        for (&b, _) in &self.decode_exes {
            if b >= n {
                return b;
            }
        }
        self.max_decode_batch()
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .map_err(|e| anyhow!("upload f32: {e:?}"))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(data, dims, None)
            .map_err(|e| anyhow!("upload i32: {e:?}"))
    }

    /// Run one prefill chunk for one sequence.
    ///
    /// * `kv` — the sequence's cache, (L,2,Hkv,S,D) flattened; updated in
    ///   place.
    /// * `tokens` — the chunk's token ids (1 <= len <= max chunk bucket).
    /// * `cache_len` — tokens already in the cache.
    ///
    /// Returns the logits of the last token of the chunk (length V) —
    /// meaningful on the final chunk of a prompt.
    pub fn prefill(&self, kv: &mut [f32], tokens: &[i32], cache_len: usize) -> Result<Vec<f32>> {
        let valid = tokens.len();
        if valid == 0 {
            bail!("empty prefill chunk");
        }
        if cache_len + valid > self.max_seq() {
            bail!("prefill overruns max_seq: {} + {}", cache_len, valid);
        }
        let bucket = self.chunk_bucket(valid);
        if valid > bucket {
            bail!("chunk of {valid} exceeds largest bucket {bucket}");
        }
        let exe = &self.prefill_exes[&bucket];

        let mut padded = vec![0i32; bucket];
        padded[..valid].copy_from_slice(tokens);

        let kv_buf = self.upload_f32(kv, &self.manifest.kv_cache_shape)?;
        let tok_buf = self.upload_i32(&padded, &[bucket])?;
        let cl_buf = self.upload_i32(&[cache_len as i32], &[1])?;
        let vl_buf = self.upload_i32(&[valid as i32], &[1])?;

        let mut args: Vec<&xla::PjRtBuffer> = self.param_bufs.iter().collect();
        args.push(&kv_buf);
        args.push(&tok_buf);
        args.push(&cl_buf);
        args.push(&vl_buf);

        let result = exe.execute_b(&args).map_err(|e| anyhow!("prefill exec: {e:?}"))?;
        let tuple = result[0][0].to_literal_sync().map_err(|e| anyhow!("sync: {e:?}"))?;
        let (logits_lit, kv_lit) =
            tuple.to_tuple2().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let logits = logits_lit.to_vec::<f32>().map_err(|e| anyhow!("logits: {e:?}"))?;
        let new_kv = kv_lit.to_vec::<f32>().map_err(|e| anyhow!("kv out: {e:?}"))?;
        kv.copy_from_slice(&new_kv);
        Ok(logits)
    }

    /// Run one batched decode step.
    ///
    /// * `kvs` — per-sequence caches, each (L,2,Hkv,S,D) flattened;
    ///   updated in place.
    /// * `tokens[i]` — the current input token of sequence i.
    /// * `positions[i]` — that token's position (cache length before it).
    ///
    /// Returns next-token logits per sequence.
    pub fn decode(
        &self,
        kvs: &mut [&mut [f32]],
        tokens: &[i32],
        positions: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        let n = kvs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        if tokens.len() != n || positions.len() != n {
            bail!("decode arity mismatch");
        }
        for &p in positions {
            if p + 1 > self.max_seq() {
                bail!("decode position {p} overruns max_seq");
            }
        }
        let bucket = self.decode_bucket(n);
        if n > bucket {
            bail!("decode batch {n} exceeds largest bucket {bucket}");
        }
        let exe = &self.decode_exes[&bucket];
        let per_seq = self.kv_elements();

        // Assemble the padded batch: inactive slots are zero KV at
        // position 0 (the model tolerates them; outputs are discarded).
        let mut kv_batch = vec![0f32; bucket * per_seq];
        for (i, kv) in kvs.iter().enumerate() {
            kv_batch[i * per_seq..(i + 1) * per_seq].copy_from_slice(kv);
        }
        let mut tok = vec![0i32; bucket];
        tok[..n].copy_from_slice(tokens);
        let mut pos = vec![0i32; bucket];
        for (i, &p) in positions.iter().enumerate() {
            pos[i] = p as i32;
        }

        let mut dims = vec![bucket];
        dims.extend_from_slice(&self.manifest.kv_cache_shape);
        let kv_buf = self.upload_f32(&kv_batch, &dims)?;
        let tok_buf = self.upload_i32(&tok, &[bucket])?;
        let pos_buf = self.upload_i32(&pos, &[bucket])?;

        let mut args: Vec<&xla::PjRtBuffer> = self.param_bufs.iter().collect();
        args.push(&kv_buf);
        args.push(&tok_buf);
        args.push(&pos_buf);

        let result = exe.execute_b(&args).map_err(|e| anyhow!("decode exec: {e:?}"))?;
        let tuple = result[0][0].to_literal_sync().map_err(|e| anyhow!("sync: {e:?}"))?;
        let (logits_lit, kv_lit) =
            tuple.to_tuple2().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let logits_flat = logits_lit.to_vec::<f32>().map_err(|e| anyhow!("logits: {e:?}"))?;
        let kv_out = kv_lit.to_vec::<f32>().map_err(|e| anyhow!("kv out: {e:?}"))?;

        let v = self.vocab_size();
        for (i, kv) in kvs.iter_mut().enumerate() {
            kv.copy_from_slice(&kv_out[i * per_seq..(i + 1) * per_seq]);
        }
        Ok((0..n).map(|i| logits_flat[i * v..(i + 1) * v].to_vec()).collect())
    }
}

/// Greedy sampling: argmax over logits.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_peak() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.9]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }
}
