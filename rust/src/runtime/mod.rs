//! PJRT runtime: loads `artifacts/*.hlo.txt` (the AOT-compiled L2 model)
//! and executes it on the request path via the `xla` crate's PJRT CPU
//! client. HLO text is the interchange format (see `python/compile/aot.py`
//! for why text, not serialized protos).

pub mod backend;
pub mod client;
pub mod manifest;
pub mod params;

pub use backend::PjrtBackend;
pub use client::{argmax, ModelRuntime};
pub use manifest::Manifest;
