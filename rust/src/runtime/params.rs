//! Reader for `artifacts/params.bin` — the binary weight format written
//! by `python/compile/params_io.py`. Keep the two in sync.

use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

const MAGIC: &[u8; 4] = b"NYMP";
const VERSION: u32 = 1;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
    /// Raw little-endian data (row-major).
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("tensor {} is not f32", self.name);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

/// Load all tensors, in file (= contract) order.
pub fn load_params(path: &Path) -> Result<Vec<Tensor>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse_params(&bytes)
}

pub fn parse_params(bytes: &[u8]) -> Result<Vec<Tensor>> {
    let mut r = bytes;
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic).context("params: truncated magic")?;
    if &magic != MAGIC {
        bail!("params: bad magic {magic:?}");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("params: unsupported version {version}");
    }
    let count = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        let mut name_buf = vec![0u8; name_len];
        r.read_exact(&mut name_buf).context("params: truncated name")?;
        let name = String::from_utf8(name_buf).context("params: non-utf8 name")?;
        let dtype = match read_u32(&mut r)? {
            0 => DType::F32,
            1 => DType::I32,
            other => bail!("params: unknown dtype {other}"),
        };
        let ndim = read_u32(&mut r)? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u64(&mut r)? as usize);
        }
        let nbytes = read_u64(&mut r)? as usize;
        let expect: usize = dims.iter().product::<usize>() * 4;
        if nbytes != expect {
            bail!("params: {name} size mismatch: {nbytes} vs {expect}");
        }
        let mut data = vec![0u8; nbytes];
        r.read_exact(&mut data).with_context(|| format!("params: truncated data for {name}"))?;
        out.push(Tensor { name, dtype, dims, data });
    }
    Ok(out)
}

fn read_u32(r: &mut &[u8]) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("params: truncated u32")?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut &[u8]) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).context("params: truncated u64")?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a file in the python writer's format.
    fn sample_file() -> Vec<u8> {
        let mut f = Vec::new();
        f.extend_from_slice(MAGIC);
        f.extend_from_slice(&VERSION.to_le_bytes());
        f.extend_from_slice(&2u32.to_le_bytes()); // two tensors
        // tensor 1: "w" f32 [2,2]
        f.extend_from_slice(&1u32.to_le_bytes());
        f.extend_from_slice(b"w");
        f.extend_from_slice(&0u32.to_le_bytes()); // f32
        f.extend_from_slice(&2u32.to_le_bytes()); // ndim
        f.extend_from_slice(&2u64.to_le_bytes());
        f.extend_from_slice(&2u64.to_le_bytes());
        f.extend_from_slice(&16u64.to_le_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            f.extend_from_slice(&v.to_le_bytes());
        }
        // tensor 2: "idx" i32 [3]
        f.extend_from_slice(&3u32.to_le_bytes());
        f.extend_from_slice(b"idx");
        f.extend_from_slice(&1u32.to_le_bytes()); // i32
        f.extend_from_slice(&1u32.to_le_bytes());
        f.extend_from_slice(&3u64.to_le_bytes());
        f.extend_from_slice(&12u64.to_le_bytes());
        for v in [7i32, 8, 9] {
            f.extend_from_slice(&v.to_le_bytes());
        }
        f
    }

    #[test]
    fn parses_sample() {
        let tensors = parse_params(&sample_file()).unwrap();
        assert_eq!(tensors.len(), 2);
        assert_eq!(tensors[0].name, "w");
        assert_eq!(tensors[0].dims, vec![2, 2]);
        assert_eq!(tensors[0].as_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(tensors[1].name, "idx");
        assert_eq!(tensors[1].dtype, DType::I32);
        assert!(tensors[1].as_f32().is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let mut f = sample_file();
        f[0] = b'X';
        assert!(parse_params(&f).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let f = sample_file();
        assert!(parse_params(&f[..f.len() - 4]).is_err());
    }

    #[test]
    fn reads_real_params_if_present() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/params.bin");
        if path.exists() {
            let tensors = load_params(&path).unwrap();
            assert_eq!(tensors[0].name, "embed");
            let total: usize = tensors.iter().map(|t| t.element_count()).sum();
            assert!(total > 1_000_000);
        }
    }
}
