//! Lightweight batch-latency predictor (paper §3.6).
//!
//! The paper trains a random forest on Vidur profiles; we use ridge
//! regression over hand-chosen features of the batch shape, fit from
//! calibration runs against the execution backend. The cost surface is
//! smooth and near-linear in these features, prediction is a dot product
//! (allocation-free on the scheduling hot path), and the fitted model is
//! backend-agnostic — calibrate against the simulator for experiments or
//! against the real PJRT runtime for serving.

use crate::simulator::cost_model::{BatchShape, BatchStats, CostModel};
use crate::util::linalg::ridge_fit;
use crate::util::Rng;

/// Feature vector of a batch: see `features()` for the definition.
pub const N_FEATURES: usize = 6;

/// Extract predictor features from a batch shape.
///
/// [1, prefill_tokens, n_decodes, decode_kv_sum/1e3,
///  prefill_attn_reads/1e6, total_tokens^2/1e6]
pub fn features(batch: &BatchShape) -> [f64; N_FEATURES] {
    features_from_stats(&BatchStats::from_shape(batch))
}

/// Features from a batch's sufficient statistics — every feature is a
/// function of the running sums [`BatchStats`] maintains, which is what
/// makes the fitted predictor usable on the scheduler's O(1) probe path.
pub fn features_from_stats(stats: &BatchStats) -> [f64; N_FEATURES] {
    let total = stats.total_tokens();
    [
        1.0,
        stats.prefill_tokens,
        stats.n_decodes as f64,
        stats.decode_kv_sum / 1e3,
        stats.prefill_attn_reads / 1e6,
        total * total / 1e6,
    ]
}

/// Linear latency predictor over `features()`.
#[derive(Debug, Clone)]
pub struct LatencyPredictor {
    weights: [f64; N_FEATURES],
    /// Residual safety floor: predictions are clamped to >= this.
    floor_s: f64,
}

impl LatencyPredictor {
    /// Predict iteration latency in seconds.
    pub fn predict(&self, batch: &BatchShape) -> f64 {
        self.predict_stats(&BatchStats::from_shape(batch))
    }

    /// Predict from sufficient statistics (O(1), allocation-free — the
    /// scheduler's incremental probe path).
    pub fn predict_stats(&self, stats: &BatchStats) -> f64 {
        let f = features_from_stats(stats);
        let mut y = 0.0;
        for i in 0..N_FEATURES {
            y += self.weights[i] * f[i];
        }
        y.max(self.floor_s)
    }

    /// Fit from (batch, measured latency) samples.
    pub fn fit(samples: &[(BatchShape, f64)]) -> Option<LatencyPredictor> {
        if samples.len() < N_FEATURES {
            return None;
        }
        let xs: Vec<Vec<f64>> = samples.iter().map(|(b, _)| features(b).to_vec()).collect();
        let ys: Vec<f64> = samples.iter().map(|&(_, y)| y).collect();
        let w = ridge_fit(&xs, &ys, 1e-6)?;
        let mut weights = [0.0; N_FEATURES];
        weights.copy_from_slice(&w);
        let floor_s = ys.iter().cloned().fold(f64::INFINITY, f64::min).max(0.0) * 0.5;
        Some(LatencyPredictor { weights, floor_s })
    }

    /// Calibrate against a cost model by sweeping representative batch
    /// shapes — the simulator-backed analogue of profiling the real
    /// engine (the paper's "performance profiles collected from Vidur").
    pub fn calibrate(model: &CostModel, seed: u64) -> LatencyPredictor {
        let mut rng = Rng::new(seed ^ 0xCA11B7A7E);
        let mut samples = Vec::new();
        // Structured grid: chunk x cache_len x decode load.
        for &chunk in &[0u32, 16, 32, 64, 128, 256, 512, 1024, 2048] {
            for &cache in &[0u32, 512, 2048, 8192] {
                for &nd in &[0usize, 1, 8, 32, 128] {
                    for &kv in &[128u32, 1024, 4096] {
                        if chunk == 0 && nd == 0 {
                            continue;
                        }
                        let mut b = BatchShape::default();
                        if chunk > 0 {
                            b.prefill.push(crate::simulator::cost_model::PrefillSegment {
                                cache_len: cache,
                                chunk,
                            });
                        }
                        b.decode_kv_lens = vec![kv; nd];
                        let y = model.iteration_latency(&b);
                        samples.push((b, y));
                    }
                }
            }
        }
        // Random shapes to cover mixed segments.
        for _ in 0..200 {
            let mut b = BatchShape::default();
            let n_seg = rng.below(3) as usize;
            for _ in 0..n_seg {
                b.prefill.push(crate::simulator::cost_model::PrefillSegment {
                    cache_len: rng.below(8192) as u32,
                    chunk: 1 + rng.below(1024) as u32,
                });
            }
            let nd = rng.below(192) as usize;
            b.decode_kv_lens = (0..nd).map(|_| 1 + rng.below(6000) as u32).collect();
            if b.is_empty() {
                continue;
            }
            let y = model.iteration_latency(&b);
            samples.push((b, y));
        }
        Self::fit(&samples).expect("calibration produces a well-posed fit")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareModel;
    use crate::simulator::cost_model::PrefillSegment;

    fn model() -> CostModel {
        CostModel::new(HardwareModel::llama3_8b_a100())
    }

    fn shape(chunk: u32, cache: u32, nd: usize, kv: u32) -> BatchShape {
        let mut b = BatchShape::default();
        if chunk > 0 {
            b.prefill.push(PrefillSegment { cache_len: cache, chunk });
        }
        b.decode_kv_lens = vec![kv; nd];
        b
    }

    #[test]
    fn calibrated_predictor_tracks_cost_model() {
        let m = model();
        let p = LatencyPredictor::calibrate(&m, 0);
        // Out-of-grid probe points: within 25% relative error.
        for (c, s0, nd, kv) in
            [(192u32, 700u32, 20usize, 900u32), (384, 3000, 60, 2000), (96, 100, 4, 300)]
        {
            let b = shape(c, s0, nd, kv);
            let want = m.iteration_latency(&b);
            let got = p.predict(&b);
            let rel = (got - want).abs() / want;
            assert!(rel < 0.25, "chunk {c}: want {want}, got {got} (rel {rel})");
        }
    }

    #[test]
    fn predictions_monotone_in_chunk() {
        let m = model();
        let p = LatencyPredictor::calibrate(&m, 0);
        let mut prev = 0.0;
        for chunk in [64u32, 256, 512, 1024, 2048] {
            let y = p.predict(&shape(chunk, 1000, 16, 1000));
            assert!(y > prev, "chunk {chunk}: {y} <= {prev}");
            prev = y;
        }
    }

    #[test]
    fn fit_requires_enough_samples() {
        let b = shape(64, 0, 0, 0);
        assert!(LatencyPredictor::fit(&[(b, 0.01)]).is_none());
    }

    #[test]
    fn predictions_have_floor() {
        let m = model();
        let p = LatencyPredictor::calibrate(&m, 0);
        let tiny = shape(1, 0, 0, 0);
        assert!(p.predict(&tiny) > 0.0);
    }

    #[test]
    fn predict_stats_matches_predict() {
        let m = model();
        let p = LatencyPredictor::calibrate(&m, 0);
        for (c, s0, nd, kv) in
            [(0u32, 0u32, 12usize, 640u32), (256, 2048, 32, 1024), (1024, 0, 0, 0)]
        {
            let b = shape(c, s0, nd, kv);
            let stats = BatchStats::from_shape(&b);
            assert_eq!(p.predict_stats(&stats), p.predict(&b));
        }
    }

    #[test]
    fn features_reflect_batch_content() {
        let a = features(&shape(256, 0, 0, 0));
        let b = features(&shape(256, 0, 32, 1024));
        assert_eq!(a[1], 256.0);
        assert_eq!(b[2], 32.0);
        assert!(b[3] > a[3]);
    }
}
