//! Runtime invariant auditor: machine-checked enforcement of the
//! cluster's documented conservation and accounting invariants.
//!
//! Enabled by `cluster.audit {"enabled": true}` or the `NIYAMA_AUDIT=1`
//! environment variable (see
//! [`crate::config::ClusterConfig::effective_audit`]); off by default.
//! When off, the cluster holds no auditor and every hook is a single
//! `Option` branch — the same zero-cost discipline as the flight
//! recorder — and runs are bit-for-bit identical with the auditor on:
//! the auditor only *reads* coordinator state and panics on violation,
//! it never feeds anything back (pinned by `tests/audit.rs`).
//!
//! At every coordinator barrier (control ticks in both event loops, the
//! merge point of every parallel superstep window) the auditor checks:
//!
//! * **conservation** — every consumed arrival is accounted exactly
//!   once: `Σ dispatched + rejected == arrivals consumed`, and
//!   `Σ dispatched == Σ (engine-pending + non-tombstone store
//!   entries)` (handoffs, drain moves and live migrations tombstone the
//!   origin entry and re-create the request at the target, so the
//!   cluster-wide count is invariant);
//! * **kv-accounting** — each engine's own KV tally (live-set sum +
//!   outbound transfer reservations) equals an independent sweep of its
//!   request store, and a *fresh* load snapshot agrees with both;
//!   prefix-cache residency stays within the ledger budget and is
//!   excluded from `kv_used`;
//! * **append-only slots** — replica slots are never removed and a
//!   slot's pool (hence its immutable spec) never changes; every
//!   per-replica vector stays index-aligned;
//! * **clock-monotonicity** — no engine's virtual clock ever moves
//!   backwards across barriers.
//!
//! At run end it additionally checks terminal states (a retired replica
//! is fully drained; a drained engine holds no active request) and that
//! every violating request's SLO-autopsy components sum to its lateness
//! ([`crate::obs::autopsy`]).
//!
//! A violation panics with a structured report carrying the seed, the
//! virtual time, the replica and the barrier ordinal — enough to replay
//! the exact failing instant deterministically.

use crate::obs::{autopsy, lateness, Autopsy};
use crate::request::{Phase, RequestStore};

/// One engine's own view of its accounting, produced by
/// [`crate::engine::Engine::audit_probe`]. Deliberately computed from
/// the engine's *internal* structures (live set, outbound reservations)
/// so the auditor can cross-check it against an independent sweep of
/// the public request store.
#[derive(Debug, Clone, Default)]
pub struct EngineAuditProbe {
    /// Engine-local virtual clock.
    pub now: f64,
    /// Size of the live set (admitted, non-terminal requests).
    pub live: usize,
    /// Dispatched-but-not-yet-admitted arrivals still queued.
    pub pending: usize,
    /// KV tokens of the live set, summed over the live ids.
    pub live_kv: u64,
    /// KV tokens reserved by outbound live-migration transfers.
    pub outbound_kv: u64,
    /// Hardware KV capacity in tokens.
    pub kv_capacity: u64,
    /// Prefix-cache resident tokens (0 when the cache is off).
    pub cache_resident: u64,
    /// Prefix-cache ledger budget in tokens (0 when the cache is off).
    pub cache_budget: u64,
    /// Whether the engine reports itself fully drained.
    pub drained: bool,
}

/// One replica slot as the auditor sees it at a barrier: the engine's
/// probe plus the coordinator's independent accounting of the same
/// quantities.
#[derive(Debug, Clone, Default)]
pub struct ReplicaAudit {
    /// Pool index of this slot (immutable from provision to retirement).
    pub pool: usize,
    /// The engine's internal accounting.
    pub probe: EngineAuditProbe,
    /// Non-tombstone entries in the request store (coordinator sweep).
    pub store_entries: usize,
    /// Active (non-terminal) entries in the request store.
    pub store_active: usize,
    /// KV tokens summed over the store's active entries.
    pub store_active_kv: u64,
    /// Arrivals the dispatcher routed here (net of drain re-dispatch).
    pub dispatched: usize,
    /// `(kv_used, active)` from the cached load snapshot, present only
    /// when the snapshot is *fresh* (not marked dirty) and must then
    /// agree with the live engine state.
    pub snapshot: Option<(u64, usize)>,
    /// Whether the coordinator has stamped this slot retired.
    pub retired: bool,
}

/// Everything the auditor inspects at one coordinator barrier.
#[derive(Debug, Clone, Default)]
pub struct ClusterAuditView {
    /// Shared cluster clock.
    pub t: f64,
    /// Control ticks executed so far (for the violation report).
    pub tick: u64,
    /// Trace arrivals consumed (dispatched or rejected) so far.
    pub arrivals: usize,
    /// Arrivals rejected by admission control, summed over tiers.
    pub rejected: usize,
    /// Per-replica slot audits, index-aligned with the engine vector.
    pub replicas: Vec<ReplicaAudit>,
    /// `(name, len)` of every per-replica coordinator vector; all must
    /// equal `replicas.len()`.
    pub aligned: Vec<(&'static str, usize)>,
}

/// The runtime invariant auditor. Owned by the cluster (boxed, behind
/// an `Option` so the disabled path is one branch); carries the
/// append-only history (slot count, slot→pool map, per-engine clock
/// floor) that barrier checks are made against.
#[derive(Debug)]
pub struct Auditor {
    seed: u64,
    /// Barriers checked so far (the violation report's ordinal).
    barriers: u64,
    /// High-water slot count: the replica set must never shrink.
    slots: usize,
    /// Pool of each slot ever seen: the prefix must never change.
    pool_of: Vec<usize>,
    /// Per-engine clock floor from the previous barrier.
    last_clock: Vec<f64>,
}

/// Relative tolerance for the autopsy-closure sum: the components are
/// built by successive subtraction from the lateness, so they re-sum to
/// it up to rounding of the same order as the values themselves.
const AUTOPSY_REL_TOL: f64 = 1e-9;

impl Auditor {
    pub fn new(seed: u64) -> Auditor {
        Auditor { seed, barriers: 0, slots: 0, pool_of: Vec::new(), last_clock: Vec::new() }
    }

    /// Barriers audited so far (tests use this to pin that the auditor
    /// actually ran).
    pub fn barriers(&self) -> u64 {
        self.barriers
    }

    fn fail(&self, check: &str, detail: &str, t: f64, replica: Option<usize>, tick: u64) -> ! {
        let replica = replica.map_or_else(|| "-".to_string(), |i| i.to_string());
        panic!(
            "NIYAMA_AUDIT violation: {check}: {detail} \
             (seed={}, t={t:.6}, replica={replica}, control_tick={tick}, barrier={})",
            self.seed,
            self.barriers
        );
    }

    /// Audit one coordinator barrier. Panics with a structured report on
    /// the first violated invariant.
    pub fn check_barrier(&mut self, v: &ClusterAuditView) {
        self.barriers += 1;
        let n = v.replicas.len();

        // Slot alignment: every per-replica vector the coordinator keeps
        // must have exactly one entry per slot.
        for &(name, len) in &v.aligned {
            if len != n {
                let d = format!("per-replica vector '{name}' has {len} entries for {n} slots");
                self.fail("slot-alignment", &d, v.t, None, v.tick);
            }
        }

        // Append-only slots: the replica set never shrinks and a slot's
        // pool never changes.
        if n < self.slots {
            let d = format!("replica set shrank from {} to {n} slots", self.slots);
            self.fail("append-only-slots", &d, v.t, None, v.tick);
        }
        for (i, r) in v.replicas.iter().enumerate().take(self.slots) {
            if r.pool != self.pool_of[i] {
                let d = format!("slot pool changed from {} to {}", self.pool_of[i], r.pool);
                self.fail("append-only-slots", &d, v.t, Some(i), v.tick);
            }
        }
        for r in v.replicas.iter().skip(self.slots) {
            self.pool_of.push(r.pool);
            self.last_clock.push(f64::NEG_INFINITY);
        }
        self.slots = n;

        // Per-engine clock monotonicity across barriers.
        for (i, r) in v.replicas.iter().enumerate() {
            if r.probe.now < self.last_clock[i] {
                let d = format!(
                    "engine clock moved backwards: {:.9} -> {:.9}",
                    self.last_clock[i],
                    r.probe.now
                );
                self.fail("clock-monotonicity", &d, v.t, Some(i), v.tick);
            }
            self.last_clock[i] = r.probe.now;
        }

        // KV accounting, engine by engine.
        for (i, r) in v.replicas.iter().enumerate() {
            let p = &r.probe;
            if p.live != r.store_active {
                let d = format!(
                    "live set has {} ids but the store holds {} active requests",
                    p.live,
                    r.store_active
                );
                self.fail("kv-accounting", &d, v.t, Some(i), v.tick);
            }
            if p.live_kv != r.store_active_kv {
                let d = format!(
                    "live-set KV {} != store active KV {} (cache residency {} must stay excluded)",
                    p.live_kv,
                    r.store_active_kv,
                    p.cache_resident
                );
                self.fail("kv-accounting", &d, v.t, Some(i), v.tick);
            }
            if let Some((snap_kv, snap_active)) = r.snapshot {
                let used = p.live_kv + p.outbound_kv;
                if snap_kv != used || snap_active != p.live {
                    let d = format!(
                        "fresh snapshot says kv_used={snap_kv} active={snap_active}, \
                         engine says kv_used={used} (live {} + outbound {}) active={}",
                        p.live_kv,
                        p.outbound_kv,
                        p.live
                    );
                    self.fail("kv-accounting", &d, v.t, Some(i), v.tick);
                }
            }
            if p.cache_resident > p.cache_budget {
                let d = format!(
                    "prefix cache holds {} tokens over its {}-token ledger budget",
                    p.cache_resident,
                    p.cache_budget
                );
                self.fail("cache-residency", &d, v.t, Some(i), v.tick);
            }
        }

        // Conservation: every consumed arrival is accounted exactly once.
        let dispatched: usize = v.replicas.iter().map(|r| r.dispatched).sum();
        if dispatched + v.rejected != v.arrivals {
            let d = format!(
                "dispatched {dispatched} + rejected {} != arrivals consumed {}",
                v.rejected,
                v.arrivals
            );
            self.fail("conservation", &d, v.t, None, v.tick);
        }
        let held: usize = v.replicas.iter().map(|r| r.probe.pending + r.store_entries).sum();
        if held != dispatched {
            let d = format!(
                "engines hold {held} requests (pending + non-tombstone store entries) \
                 but {dispatched} were dispatched"
            );
            self.fail("conservation", &d, v.t, None, v.tick);
        }
    }

    /// Audit the end of a run: everything a barrier checks, plus
    /// terminal-state conservation and the SLO-autopsy closure over
    /// every violating finished request.
    pub fn check_run_end(&mut self, v: &ClusterAuditView, stores: &[&RequestStore]) {
        self.check_barrier(v);
        for (i, r) in v.replicas.iter().enumerate() {
            if r.retired && !r.probe.drained {
                let d = "replica retired while not drained";
                self.fail("terminal-states", d, v.t, Some(i), v.tick);
            }
            if r.probe.drained && r.store_active != 0 {
                let d = format!("drained engine still holds {} active requests", r.store_active);
                self.fail("terminal-states", &d, v.t, Some(i), v.tick);
            }
        }
        for (i, store) in stores.iter().enumerate() {
            for req in store.iter() {
                if req.phase != Phase::Finished {
                    continue;
                }
                if let Some(a) = autopsy(req) {
                    if let Some(d) = autopsy_closure_violation(&a) {
                        self.fail("autopsy-closure", &d, v.t, Some(i), v.tick);
                    }
                    let l = lateness(req);
                    if (a.lateness_s - l).abs() > AUTOPSY_REL_TOL * l.abs().max(1.0) {
                        let d = format!(
                            "autopsy carries lateness {:.9} but the request's is {l:.9}",
                            a.lateness_s
                        );
                        self.fail("autopsy-closure", &d, v.t, Some(i), v.tick);
                    }
                }
            }
        }
    }
}

/// Why `a`'s components fail to decompose its lateness, or `None` when
/// the closure holds: every component non-negative, none exceeding the
/// total, and the six summing back to it within rounding.
fn autopsy_closure_violation(a: &Autopsy) -> Option<String> {
    let parts = [
        ("warmup", a.warmup_s),
        ("queueing", a.queueing_s),
        ("migration", a.migration_s),
        ("chunk", a.chunk_s),
        ("degrade", a.degrade_s),
        ("other", a.other_s),
    ];
    for (name, x) in parts {
        if x < 0.0 {
            return Some(format!("component {name} is negative ({x:.9})"));
        }
    }
    let sum: f64 = parts.iter().map(|(_, x)| x).sum();
    let tol = AUTOPSY_REL_TOL * a.lateness_s.abs().max(1.0);
    if (sum - a.lateness_s).abs() > tol {
        return Some(format!("components sum to {sum:.9} but lateness is {:.9}", a.lateness_s));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A two-replica view whose numbers satisfy every invariant.
    fn clean_view() -> ClusterAuditView {
        let r0 = ReplicaAudit {
            pool: 0,
            probe: EngineAuditProbe {
                now: 12.5,
                live: 2,
                pending: 1,
                live_kv: 300,
                outbound_kv: 50,
                kv_capacity: 10_000,
                cache_resident: 128,
                cache_budget: 1024,
                drained: false,
            },
            store_entries: 5, // 2 active + 3 finished
            store_active: 2,
            store_active_kv: 300,
            dispatched: 6,
            snapshot: Some((350, 2)),
            retired: false,
        };
        let r1 = ReplicaAudit {
            pool: 1,
            probe: EngineAuditProbe { now: 11.0, live: 1, live_kv: 80, ..Default::default() },
            store_entries: 4, // 1 active + 3 finished
            store_active: 1,
            store_active_kv: 80,
            dispatched: 4,
            snapshot: None, // dirty snapshot: exempt from the coherence check
            retired: false,
        };
        ClusterAuditView {
            t: 12.5,
            tick: 3,
            arrivals: 11,
            rejected: 1, // 6 + 4 dispatched + 1 rejected = 11 consumed
            replicas: vec![r0, r1],
            aligned: vec![("states", 2), ("snaps", 2)],
        }
    }

    #[test]
    fn clean_barriers_pass_and_count() {
        let mut a = Auditor::new(7);
        a.check_barrier(&clean_view());
        a.check_barrier(&clean_view());
        assert_eq!(a.barriers(), 2);
    }

    #[test]
    #[should_panic(expected = "NIYAMA_AUDIT violation: conservation")]
    fn seeded_conservation_violation_fires() {
        let mut v = clean_view();
        v.replicas[0].dispatched += 1; // an arrival counted twice
        Auditor::new(7).check_barrier(&v);
    }

    #[test]
    #[should_panic(expected = "NIYAMA_AUDIT violation: conservation")]
    fn seeded_lost_request_fires() {
        let mut v = clean_view();
        v.replicas[1].store_entries -= 1; // a request vanished
        Auditor::new(7).check_barrier(&v);
    }

    #[test]
    #[should_panic(expected = "NIYAMA_AUDIT violation: kv-accounting")]
    fn seeded_kv_leak_fires() {
        let mut v = clean_view();
        v.replicas[0].probe.live_kv += 64; // engine tally drifted off the store
        Auditor::new(7).check_barrier(&v);
    }

    #[test]
    #[should_panic(expected = "NIYAMA_AUDIT violation: kv-accounting")]
    fn seeded_stale_fresh_snapshot_fires() {
        let mut v = clean_view();
        v.replicas[0].snapshot = Some((351, 2)); // claims fresh, disagrees
        Auditor::new(7).check_barrier(&v);
    }

    #[test]
    #[should_panic(expected = "NIYAMA_AUDIT violation: cache-residency")]
    fn seeded_cache_overrun_fires() {
        let mut v = clean_view();
        v.replicas[0].probe.cache_resident = v.replicas[0].probe.cache_budget + 1;
        Auditor::new(7).check_barrier(&v);
    }

    #[test]
    #[should_panic(expected = "NIYAMA_AUDIT violation: append-only-slots")]
    fn seeded_slot_removal_fires() {
        let mut a = Auditor::new(7);
        a.check_barrier(&clean_view());
        let mut v = clean_view();
        v.replicas.pop();
        v.aligned = vec![("states", 1), ("snaps", 1)];
        a.check_barrier(&v);
    }

    #[test]
    #[should_panic(expected = "NIYAMA_AUDIT violation: append-only-slots")]
    fn seeded_pool_mutation_fires() {
        let mut a = Auditor::new(7);
        a.check_barrier(&clean_view());
        let mut v = clean_view();
        v.replicas[1].pool = 0; // a slot's immutable spec changed
        a.check_barrier(&v);
    }

    #[test]
    #[should_panic(expected = "NIYAMA_AUDIT violation: clock-monotonicity")]
    fn seeded_clock_reversal_fires() {
        let mut a = Auditor::new(7);
        a.check_barrier(&clean_view());
        let mut v = clean_view();
        v.replicas[0].probe.now -= 1.0;
        a.check_barrier(&v);
    }

    #[test]
    #[should_panic(expected = "NIYAMA_AUDIT violation: slot-alignment")]
    fn seeded_vector_misalignment_fires() {
        let mut v = clean_view();
        v.aligned.push(("retired_at", 3));
        Auditor::new(7).check_barrier(&v);
    }

    #[test]
    #[should_panic(expected = "NIYAMA_AUDIT violation: terminal-states")]
    fn seeded_undrained_retirement_fires() {
        let mut v = clean_view();
        v.replicas[0].retired = true; // retired with live work
        Auditor::new(7).check_run_end(&v, &[]);
    }

    #[test]
    fn autopsy_closure_detects_bad_decompositions() {
        let good = Autopsy {
            lateness_s: 3.0,
            warmup_s: 0.5,
            queueing_s: 1.0,
            migration_s: 0.0,
            chunk_s: 0.25,
            degrade_s: 0.0,
            other_s: 1.25,
        };
        assert!(autopsy_closure_violation(&good).is_none());
        let leaky = Autopsy { other_s: 0.25, ..good }; // sums to 2.0, not 3.0
        let msg = autopsy_closure_violation(&leaky).expect("must flag a non-closing sum");
        assert!(msg.contains("components sum"));
        let negative = Autopsy { queueing_s: -1.0, ..good };
        let msg = autopsy_closure_violation(&negative).expect("must flag a negative component");
        assert!(msg.contains("negative"));
    }

    #[test]
    fn violation_reports_carry_the_replay_coordinates() {
        let mut v = clean_view();
        v.replicas[0].probe.cache_resident = 9999;
        let err = std::panic::catch_unwind(|| Auditor::new(42).check_barrier(&v))
            .expect_err("must panic");
        let msg = err.downcast_ref::<String>().expect("string payload");
        assert!(msg.contains("seed=42"), "report must carry the seed: {msg}");
        assert!(msg.contains("t=12.5"), "report must carry the virtual time: {msg}");
        assert!(msg.contains("replica=0"), "report must carry the replica: {msg}");
        assert!(msg.contains("control_tick=3"), "report must carry the tick: {msg}");
    }
}
