//! End-to-end benchmark: regenerates reduced versions of every paper
//! table/figure and reports wall time per experiment (`cargo bench`).
//!
//! The full-resolution versions run via `niyama repro --id <...>`
//! (standard scale) or `--full` (paper scale); this bench uses the quick
//! scale so `cargo bench` finishes in minutes while still exercising
//! every experiment path end-to-end.

use niyama::repro::{self, Scale};
use std::time::Instant;

fn main() {
    let scale = Scale::quick();
    println!("== paper experiment regeneration (quick scale) ==\n");
    let mut failures = 0;
    for id in repro::ALL_IDS {
        let t0 = Instant::now();
        println!("--- {id} ---");
        match repro::run(id, scale) {
            Ok(()) => println!("[{id}] ok in {:.2}s\n", t0.elapsed().as_secs_f64()),
            Err(e) => {
                failures += 1;
                println!("[{id}] FAILED: {e}\n");
            }
        }
    }
    if failures > 0 {
        eprintln!("{failures} experiment(s) failed");
        std::process::exit(1);
    }
    println!("all experiments regenerated");
}
