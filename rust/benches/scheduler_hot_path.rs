//! Scheduler hot-path microbenchmarks (`cargo bench`).
//!
//! No criterion in the offline environment, so this is a minimal
//! measured-loop harness: warmup, N timed iterations, median/p99 of
//! per-iteration time. The L3 perf target (DESIGN.md §Perf): one
//! scheduling decision must stay well under 1 ms so the coordinator
//! never bottlenecks a ~25 ms GPU iteration.
//!
//! Besides the human-readable table, the run emits
//! `BENCH_scheduler_hot_path.json` (override the path with
//! `NIYAMA_BENCH_JSON`) so the perf trajectory is tracked across PRs;
//! `NIYAMA_BENCH_ITERS` caps per-case iterations for CI smoke runs.
//! `tools/bench_diff` compares two of these JSON files and gates on
//! regressions past a threshold; the cluster rows additionally get a
//! profiler-on `.prof` twin whose wall-clock split (coordinator /
//! stripe / barrier, per-worker utilization) lands in the `profiles`
//! section.

use niyama::config::{Config, HardwareModel, Policy, SchedulerConfig};
use niyama::predictor::LatencyPredictor;
use niyama::qos::{Importance, Slo};
use niyama::request::{RequestSpec, RequestStore};
use niyama::scheduler::{NiyamaScheduler, PlanContext, SarathiPolicy, SarathiScheduler, Scheduler};
use niyama::simulator::{BatchShape, CostModel, PrefillSegment};
use niyama::util::Rng;
use std::sync::Arc;
use std::time::Instant;

/// One benchmark case's summary, in microseconds per iteration.
struct BenchStat {
    name: String,
    median_us: f64,
    p99_us: f64,
    iters_per_s: f64,
}

/// Cap on per-case iterations (`NIYAMA_BENCH_ITERS`), for smoke runs.
fn iter_cap() -> usize {
    std::env::var("NIYAMA_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(usize::MAX)
}

fn bench<F: FnMut()>(out: &mut Vec<BenchStat>, name: &str, iters: usize, mut f: F) {
    let iters = iters.min(iter_cap()).max(3);
    // Warmup.
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let len = samples.len();
    let med = samples[len / 2];
    // Clamp: at small N the raw index `(len * 0.99) as usize` reaches
    // `len` and used to wrap to sample[0] via `% len`.
    let p99 = samples[((len as f64 * 0.99) as usize).min(len - 1)];
    let total: f64 = samples.iter().sum();
    let iters_per_s = iters as f64 / total;
    println!(
        "{name:<44} {:>10.3} us/iter (p99 {:>10.3} us, {:>8.0} it/s)",
        med * 1e6,
        p99 * 1e6,
        iters_per_s
    );
    out.push(BenchStat {
        name: name.trim().to_string(),
        median_us: med * 1e6,
        p99_us: p99 * 1e6,
        iters_per_s,
    });
}

/// Build a scheduler state with `n_prefill` queued prompts and
/// `n_decode` in-flight decodes.
fn populate(
    sched: &mut dyn Scheduler,
    store: &mut RequestStore,
    n_prefill: usize,
    n_decode: usize,
    seed: u64,
) {
    let mut rng = Rng::new(seed);
    for i in 0..n_prefill + n_decode {
        let slo = match i % 3 {
            0 => Slo::Interactive { ttft_s: 6.0, tbt_s: 0.05 },
            1 => Slo::NonInteractive { ttlt_s: 600.0 },
            _ => Slo::NonInteractive { ttlt_s: 1800.0 },
        };
        let prompt = 64 + rng.below(4000) as u32;
        let id = store.insert(
            RequestSpec {
                arrival_s: i as f64 * 0.01,
                prompt_tokens: prompt,
                decode_tokens: 1 + rng.below(400) as u32,
                tier: i % 3,
                app_id: (i % 3) as u32,
                importance: if i % 5 == 0 { Importance::Low } else { Importance::High },
                session_id: None,
                prefix_tokens: 0,
            },
            slo,
        );
        sched.on_arrival(id, store);
        if i >= n_prefill {
            {
                let r = store.get_mut(id);
                r.prefilled = r.spec.prompt_tokens;
                r.phase = niyama::request::Phase::Decode;
                r.emit_token(r.spec.arrival_s + 0.5);
            }
            sched.on_prefill_complete(id, store);
        }
    }
}

/// Escape nothing fancy: bench names are plain ASCII identifiers.
fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn write_json(
    stats: &[BenchStat],
    sims: &[(String, usize, u64, f64)],
    sessions: &[(String, f64, u64, f64)],
    profiles: &[(String, niyama::obs::prof::ProfileSummary)],
) {
    let path = std::env::var("NIYAMA_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_scheduler_hot_path.json".to_string());
    let mut s = String::new();
    s.push_str("{\n  \"schema\": \"niyama-scheduler-hot-path-v1\",\n  \"cases\": [\n");
    for (i, b) in stats.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"median_us\": {:.3}, \"p99_us\": {:.3}, \
             \"iters_per_s\": {:.1}}}{}\n",
            json_escape(&b.name),
            b.median_us,
            b.p99_us,
            b.iters_per_s,
            if i + 1 < stats.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n  \"end_to_end\": [\n");
    for (i, (name, reqs, iters, wall)) in sims.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"requests\": {}, \"iterations\": {}, \
             \"wall_s\": {:.3}, \"iters_per_s\": {:.1}}}{}\n",
            json_escape(name),
            reqs,
            iters,
            wall,
            *iters as f64 / wall,
            if i + 1 < sims.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n  \"sessions\": [\n");
    for (i, (name, hit_rate, saved, wall)) in sessions.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"hit_rate\": {:.4}, \"prefill_tokens_saved\": {}, \
             \"wall_s\": {:.3}}}{}\n",
            json_escape(name),
            hit_rate,
            saved,
            wall,
            if i + 1 < sessions.len() { "," } else { "" }
        ));
    }
    // Additive section (still schema v1): wall-clock split of the
    // profiler-on cluster rows. `bench_diff` ignores sections it has no
    // gate for, so readers of the v1 schema are unaffected.
    s.push_str("  ],\n  \"profiles\": [\n");
    for (i, (name, p)) in profiles.iter().enumerate() {
        let util_min =
            p.worker_util.iter().map(|w| w.utilization_pct).fold(f64::INFINITY, f64::min);
        let util_max = p.worker_util.iter().map(|w| w.utilization_pct).fold(0.0f64, f64::max);
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"workers\": {}, \"supersteps\": {}, \
             \"coordinator_s\": {:.6}, \"stripe_busy_s\": {:.6}, \"barrier_wait_s\": {:.6}, \
             \"util_min_pct\": {:.2}, \"util_max_pct\": {:.2}}}{}\n",
            json_escape(name),
            p.workers,
            p.supersteps,
            p.coordinator_total_s,
            p.stripe_busy_s,
            p.barrier_wait_s,
            if util_min.is_finite() { util_min } else { 0.0 },
            util_max,
            if i + 1 < profiles.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write(&path, s) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn main() {
    println!("== scheduler hot path (lower is better) ==");
    let cfg = Config::default();
    let model = Arc::new(CostModel::new(HardwareModel::llama3_8b_a100()));
    let mut stats: Vec<BenchStat> = Vec::new();

    for (np, nd) in [(8usize, 16usize), (64, 64), (256, 128), (1024, 256)] {
        let mut sched = NiyamaScheduler::new(cfg.scheduler.clone(), model.clone());
        let mut store = RequestStore::new();
        populate(&mut sched, &mut store, np, nd, 42);
        let ctx = PlanContext { now: 5.0, kv_capacity: 4_000_000, kv_used: 0 };
        bench(&mut stats, &format!("niyama.plan  q={np:<5} decodes={nd}"), 300, || {
            let b = sched.plan(ctx, &mut store);
            std::hint::black_box(b);
        });
    }

    // Reference (pre-incremental) costing on the heaviest case: the
    // speedup ratio of the two `q=1024` rows is the PR's headline number.
    {
        let mut ref_cfg = cfg.scheduler.clone();
        ref_cfg.reference_costing = true;
        let mut sched = NiyamaScheduler::new(ref_cfg, model.clone());
        let mut store = RequestStore::new();
        populate(&mut sched, &mut store, 1024, 256, 42);
        let ctx = PlanContext { now: 5.0, kv_capacity: 4_000_000, kv_used: 0 };
        bench(&mut stats, "niyama.plan  q=1024  decodes=256 (reference)", 100, || {
            let b = sched.plan(ctx, &mut store);
            std::hint::black_box(b);
        });
    }

    for policy in [SarathiPolicy::Fcfs, SarathiPolicy::Edf, SarathiPolicy::Srpf] {
        let mut sched = SarathiScheduler::new(
            policy,
            SchedulerConfig::sarathi(Policy::SarathiFcfs, 256),
            model.clone(),
        );
        let mut store = RequestStore::new();
        populate(&mut sched, &mut store, 256, 128, 43);
        let ctx = PlanContext { now: 5.0, kv_capacity: 4_000_000, kv_used: 0 };
        bench(&mut stats, &format!("sarathi.plan {policy:?} q=256 decodes=128"), 300, || {
            let b = sched.plan(ctx, &mut store);
            std::hint::black_box(b);
        });
    }

    println!("\n== latency models ==");
    let cm = CostModel::new(HardwareModel::llama3_8b_a100());
    let mut shape = BatchShape::default();
    shape.prefill.push(PrefillSegment { cache_len: 2048, chunk: 256 });
    shape.decode_kv_lens = (0..128).map(|i| 256 + i * 16).collect();
    bench(&mut stats, "cost_model.iteration_latency (128 decodes)", 10_000, || {
        std::hint::black_box(cm.iteration_latency(&shape));
    });
    {
        use niyama::simulator::BatchStats;
        let st = BatchStats::from_shape(&shape);
        bench(&mut stats, "cost_model.latency_from_stats (128 decodes)", 10_000, || {
            std::hint::black_box(cm.latency_from_stats(&st));
        });
    }
    let pred = LatencyPredictor::calibrate(&cm, 0);
    bench(&mut stats, "predictor.predict            (128 decodes)", 10_000, || {
        std::hint::black_box(pred.predict(&shape));
    });

    println!("\n== cluster dispatch decision cost per arrival ==");
    {
        use niyama::config::{DispatchConfig, DispatchPolicy};
        use niyama::engine::LoadSnapshot;
        use niyama::simulator::dispatch::build_dispatcher;
        let spec = RequestSpec {
            arrival_s: 100.0,
            prompt_tokens: 2048,
            decode_tokens: 64,
            tier: 0,
            app_id: 0,
            importance: Importance::High,
            session_id: None,
            prefix_tokens: 0,
        };
        let slo = Slo::Interactive { ttft_s: 6.0, tbt_s: 0.05 };
        for replicas in [8usize, 32] {
            // Synthetic but varied snapshots: the dispatcher's cost is a
            // pure function of the snapshot slice, so this isolates the
            // per-arrival decision from simulation noise.
            let snaps: Vec<LoadSnapshot> = (0..replicas)
                .map(|i| LoadSnapshot {
                    now: 100.0,
                    active: 8 + (i * 5) % 23,
                    backlog: (i * 13) % 11,
                    queued_prefill_tokens: ((i as u64 * 977) % 9000),
                    relegated_prefill_tokens: ((i as u64 * 131) % 2000),
                    queued_prefill_s: (i as f64 * 0.37) % 3.0,
                    queued_prefill_s_per_tier: vec![(i as f64 * 0.37) % 3.0, 0.0, 0.0],
                    decodes: 16,
                    kv_used: (i as u64 * 31_000) % 400_000,
                    kv_committed: (i as u64 * 700) % 5000,
                    kv_capacity: 430_000,
                    tier_slack_s: vec![4.0 - (i % 7) as f64, 300.0, 900.0],
                    sec_per_prefill_token: 3.2e-4,
                    sec_per_decode_token: 0.03,
                    kv_bytes_per_token: 131_072.0,
                    chunk_size: 256,
                    max_batch_decodes: 256,
                    tier_affinity_mask: 0,
                    cache_sessions: Vec::new(),
                    cache_resident_tokens: 0,
                })
                .collect();
            for policy in [
                DispatchPolicy::RoundRobin,
                DispatchPolicy::JoinShortestQueue,
                DispatchPolicy::LeastLoaded,
                DispatchPolicy::PowerOfTwoChoices,
                DispatchPolicy::CacheAffinity,
            ] {
                let mut d = build_dispatcher(&DispatchConfig {
                    policy,
                    relegation_handoff: false,
                    seed: 0,
                });
                bench(
                    &mut stats,
                    &format!("dispatch.{:<21} replicas={replicas}", policy.name()),
                    10_000,
                    || {
                        std::hint::black_box(d.dispatch(&spec, slo, &snaps));
                    },
                );
            }
        }
    }

    println!("\n== end-to-end simulation throughput ==");
    use niyama::engine::Engine;
    use niyama::workload::datasets::Dataset;
    use niyama::workload::WorkloadSpec;
    let mut sims: Vec<(String, usize, u64, f64)> = Vec::new();
    let sim_duration = if iter_cap() < 300 { 30.0 } else { 300.0 };
    for (name, policy) in [("niyama", None), ("sarathi-fcfs", Some(Policy::SarathiFcfs))] {
        let mut c = Config::default();
        if let Some(p) = policy {
            c.scheduler = SchedulerConfig::sarathi(p, 256);
        }
        let spec = WorkloadSpec::uniform(Dataset::azure_code(), 3.0, sim_duration);
        let trace = spec.generate(&mut Rng::new(9));
        let n = trace.len();
        let t0 = Instant::now();
        let mut eng = Engine::sim(&c);
        eng.submit_trace(trace);
        eng.run(4000.0);
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "sim {name:<14} {n} reqs, {} iters in {wall:.3}s ({:.0} iters/s, {:.0}x real-time)",
            eng.stats.iterations,
            eng.stats.iterations as f64 / wall,
            eng.now() / wall
        );
        sims.push((format!("sim.{name}"), n, eng.stats.iterations, wall));
    }

    println!("\n== cluster loop: sequential vs sharded supersteps ==");
    let mut profiles: Vec<(String, niyama::obs::prof::ProfileSummary)> = Vec::new();
    {
        use niyama::config::{DispatchPolicy, ParallelConfig, ProfilingConfig};
        use niyama::simulator::cluster::Cluster;
        // Static fleet, no control plane: these rows isolate the event
        // loop itself, so the w=1 column is the sequential oracle and
        // the w>1 columns show what the superstep sharding buys (or
        // costs — at 8 replicas barrier overhead should dominate).
        let cluster_duration = if iter_cap() < 300 { 10.0 } else { 120.0 };
        for replicas in [8usize, 64, 256] {
            let spec = WorkloadSpec::uniform(
                Dataset::azure_code(),
                replicas as f64 * 2.0,
                cluster_duration,
            );
            let trace = spec.generate(&mut Rng::new(11));
            let n = trace.len();
            for workers in [1usize, 4, 8] {
                let mut c = Config::default();
                c.cluster.dispatch.policy = DispatchPolicy::LeastLoaded;
                c.cluster.parallel = Some(ParallelConfig { workers });
                let t0 = Instant::now();
                let mut cl = Cluster::new(&c, replicas);
                cl.submit_trace(trace.clone());
                cl.run(4000.0);
                let wall = t0.elapsed().as_secs_f64();
                let events = cl.stats.events;
                println!(
                    "cluster r={replicas:<4} w={workers} {n} reqs, {events} events in {wall:.3}s \
                     ({:.0} events/s)",
                    events as f64 / wall
                );
                sims.push((format!("cluster.r{replicas}.w{workers}"), n, events, wall));

                // Profiler-on twin: the delta between this row and the
                // one above is exactly what the profiler costs when on,
                // and its summary is the worker-utilization story for
                // this (replicas, workers) point.
                c.cluster.profiling = Some(ProfilingConfig { enabled: true });
                let t0 = Instant::now();
                let mut cl = Cluster::new(&c, replicas);
                cl.submit_trace(trace.clone());
                cl.run(4000.0);
                let wall = t0.elapsed().as_secs_f64();
                let events = cl.stats.events;
                let name = format!("cluster.r{replicas}.w{workers}.prof");
                let p = cl.profile_summary().expect("profiling was enabled");
                let utils: Vec<String> =
                    p.worker_util.iter().map(|w| format!("{:.0}", w.utilization_pct)).collect();
                println!(
                    "        prof twin   {events} events in {wall:.3}s — coord {:.4}s, \
                     stripe {:.4}s, barrier {:.4}s, util [{}]%",
                    p.coordinator_total_s,
                    p.stripe_busy_s,
                    p.barrier_wait_s,
                    utils.join(" ")
                );
                sims.push((name.clone(), n, events, wall));
                profiles.push((name, p));
            }
        }
    }

    println!("\n== flight recorder: traced cluster loop ==");
    {
        use niyama::config::{DispatchPolicy, ObservabilityConfig, ParallelConfig};
        use niyama::simulator::cluster::Cluster;
        // The `cluster.r*.w*` rows above ARE the recorder-off baseline:
        // with `observability` unset every hook is a null-pointer check,
        // so any drift in those rows across PRs is the zero-cost-when-off
        // regression guard. These rows price the recorder when it is ON
        // (trace + series both recording) on the same workload.
        let cluster_duration = if iter_cap() < 300 { 10.0 } else { 120.0 };
        let replicas = 8usize;
        let spec =
            WorkloadSpec::uniform(Dataset::azure_code(), replicas as f64 * 2.0, cluster_duration);
        let trace = spec.generate(&mut Rng::new(11));
        let n = trace.len();
        for workers in [1usize, 8] {
            let mut c = Config::default();
            c.cluster.dispatch.policy = DispatchPolicy::LeastLoaded;
            c.cluster.parallel = Some(ParallelConfig { workers });
            c.cluster.observability = Some(ObservabilityConfig { trace: true, series: true });
            let t0 = Instant::now();
            let mut cl = Cluster::new(&c, replicas);
            cl.submit_trace(trace.clone());
            cl.run(4000.0);
            let wall = t0.elapsed().as_secs_f64();
            let events = cl.stats.events;
            let recorded: usize = cl.coordinator_trace().map_or(0, |b| b.len())
                + cl.engines().iter().filter_map(|e| e.trace()).map(|b| b.len()).sum::<usize>();
            println!(
                "cluster r={replicas:<4} w={workers} {n} reqs, {events} events, {recorded} \
                 recorded in {wall:.3}s ({:.0} events/s)",
                events as f64 / wall
            );
            sims.push((format!("cluster.r{replicas}.w{workers}.recorded"), n, events, wall));
        }
    }

    println!("\n== session serving: prefix-cache hit rates ==");
    let mut sessions: Vec<(String, f64, u64, f64)> = Vec::new();
    {
        use niyama::repro::sessions::{run_sessions, VARIANTS};
        let session_duration = if iter_cap() < 300 { 60.0 } else { 240.0 };
        for v in VARIANTS {
            let t0 = Instant::now();
            let s = run_sessions(v, 0.4, session_duration, 9);
            let wall = t0.elapsed().as_secs_f64();
            println!(
                "sessions.{:<16} hit_rate {:>6.1}% saved {:>9} prefill tokens \
                 ({} turns in {wall:.3}s)",
                v.name,
                100.0 * s.cache_hit_rate(),
                s.prefill_tokens_saved,
                s.total
            );
            sessions.push((
                format!("sessions.{}", v.name),
                s.cache_hit_rate(),
                s.prefill_tokens_saved,
                wall,
            ));
        }
    }

    write_json(&stats, &sims, &sessions, &profiles);
}
